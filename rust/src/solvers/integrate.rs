//! The numerical-integration driver (paper Algorithm 1): fixed-step and
//! adaptive-step loops over any [`Solver`], with an observer hook that the
//! four gradient protocols use to record exactly what they each need
//! (nothing for MALI beyond the accepted grid, checkpoints for ACA, the
//! full trial tape for naive).
//!
//! Supports reverse-time integration (`t1 < t0`) — the adjoint method's
//! backward IVP runs through the same loop.
//!
//! # Observation grids
//!
//! Time-series losses attach at *many* observation times `t₁ … t_K`, not
//! just the endpoint.  [`ObsGrid`] makes those times first-class:
//! [`integrate_obs`] / [`integrate_batch_obs`] land **exactly** (bitwise
//! `t == tᵢ`) on every observation — the adaptive controller clamps `h`
//! to the nearest barrier (next observation, else the endpoint) when it
//! would overshoot, and fixed-step runs split the span at the
//! observations (`⌈|seg|/h⌉` equal steps per segment, the same grid a
//! segment-wise caller would have produced).  Each hit fires
//! [`StepObserver::on_observation`] with the state at `tᵢ`.  With an
//! empty grid every controller decision is identical to the plain
//! [`integrate`] loop, which is itself just `integrate_obs` with no
//! observations.

use super::batch::BatchState;
use super::dynamics::Dynamics;
use super::workspace::{BatchWorkspace, SolverWorkspace};
use super::{Solver, State};
use crate::tensor::{error_norm, error_seminorm};
use crate::util::pool::{DisjointRowsMut, WorkerPool};
use anyhow::{bail, ensure, Result};

/// Step-size policy.
#[derive(Debug, Clone)]
pub enum StepMode {
    /// Fixed step of magnitude `h` (sign is derived from direction).
    Fixed { h: f64 },
    /// Adaptive control: accept when the scaled error norm ≤ 1.
    Adaptive {
        rtol: f64,
        atol: f64,
        h_init: f64,
        h_min: f64,
        h_max: f64,
    },
}

impl StepMode {
    pub fn adaptive(rtol: f64, atol: f64) -> StepMode {
        StepMode::Adaptive {
            rtol,
            atol,
            h_init: 0.25,
            h_min: 1e-6,
            h_max: 10.0,
        }
    }
}

/// Error-norm selection: `Semi` masks components out of the norm (the
/// adjoint-seminorm trick of Kidger et al., used as the SemiNorm baseline).
#[derive(Debug, Clone)]
pub enum ErrorNorm {
    Full,
    Semi(Vec<bool>),
}

impl ErrorNorm {
    fn eval(&self, err: &[f32], z0: &[f32], z1: &[f32], rtol: f64, atol: f64) -> f64 {
        match self {
            ErrorNorm::Full => error_norm(err, z0, z1, rtol, atol),
            ErrorNorm::Semi(mask) => error_seminorm(err, z0, z1, mask, rtol, atol),
        }
    }
}

/// A sorted grid of observation times `t₁ < t₂ < … < t_K` (strictly
/// monotone in the integration direction, each inside the open-closed
/// span `(t₀, t₁]`) at which a time-series loss reads the state.
///
/// The integration loops guarantee an accepted step ends **bitwise** on
/// every grid time — the invariant the multi-observation gradient
/// methods' cotangent injection relies on.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ObsGrid {
    times: Vec<f64>,
}

impl ObsGrid {
    /// The empty grid: plain endpoint-only integration.
    pub fn none() -> ObsGrid {
        ObsGrid { times: Vec::new() }
    }

    /// Build a grid from strictly monotone, finite observation times
    /// (increasing for forward-time solves, decreasing for reverse-time).
    pub fn new(times: Vec<f64>) -> Result<ObsGrid> {
        ensure!(
            times.iter().all(|t| t.is_finite()),
            "observation times must be finite: {times:?}"
        );
        ensure!(
            times.windows(2).all(|w| w[1] > w[0])
                || times.windows(2).all(|w| w[1] < w[0]),
            "observation times must be strictly monotone: {times:?}"
        );
        Ok(ObsGrid { times })
    }

    /// `k` observations evenly spaced over `(t0, t1]`, the last exactly
    /// `t1` — the layout of the latent-ODE prediction frames.
    pub fn uniform(t0: f64, t1: f64, k: usize) -> ObsGrid {
        let times = (1..=k)
            .map(|i| {
                if i == k {
                    t1
                } else {
                    t0 + (t1 - t0) * (i as f64 / k as f64)
                }
            })
            .collect();
        ObsGrid { times }
    }

    pub fn len(&self) -> usize {
        self.times.len()
    }

    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    /// Observation time `t_k` (0-indexed).
    pub fn time(&self, k: usize) -> f64 {
        self.times[k]
    }

    pub fn times(&self) -> &[f64] {
        &self.times
    }

    /// Check every observation lies in the open-closed span `(t0, t1]`,
    /// ordered in the integration direction.  Crate-visible so the
    /// serving layer can validate a request class once at construction
    /// instead of per solve.
    pub(crate) fn validate_for(&self, t0: f64, t1: f64) -> Result<()> {
        let dir = (t1 - t0).signum();
        for (k, &t) in self.times.iter().enumerate() {
            ensure!(
                (t - t0) * dir > 0.0 && (t1 - t) * dir >= 0.0,
                "observation t[{k}] = {t} outside the open-closed span ({t0}, {t1}]"
            );
        }
        if let Some(w) = self.times.windows(2).find(|w| (w[1] - w[0]) * dir <= 0.0) {
            bail!(
                "observation times {w:?} not strictly ordered in the \
                 integration direction {t0} → {t1}"
            );
        }
        Ok(())
    }
}

/// An accepted step, as seen by observers.
pub struct AcceptedStep<'a> {
    pub index: usize,
    /// Step start time and (signed) size; the step ends at `t_end`.
    pub t: f64,
    pub h: f64,
    /// Exact end time of the step: `t + h`, except snapped bitwise onto
    /// the barrier (observation time or endpoint) the step was clamped to.
    pub t_end: f64,
    pub before: &'a State,
    pub after: &'a State,
    /// Inner-loop iterations spent on this step (1 = accepted first try).
    pub trials: usize,
}

/// Observer for the integration loop.  Default impls ignore everything, so
/// plain inference passes `&mut ()`.
pub trait StepObserver {
    fn on_accept(&mut self, _step: &AcceptedStep) {}
    /// Every trial (accepted or rejected) with the state bytes it
    /// materialized — the naive method's tape accounting.
    fn on_trial(&mut self, _t: f64, _h: f64, _state_bytes: usize, _accepted: bool) {}
    /// The trajectory reached observation `k` of the [`ObsGrid`] — fired
    /// once per observation, in grid order, with `t` bitwise equal to the
    /// grid time and `state` the solution there.
    fn on_observation(&mut self, _k: usize, _t: f64, _state: &State) {}
}

impl StepObserver for () {}

/// Statistics of one integration run.
#[derive(Debug, Clone, Default)]
pub struct IntStats {
    pub n_accepted: usize,
    pub n_trials: usize,
    pub f_evals: u64,
}

impl IntStats {
    /// Average inner iterations per accepted step — the paper's `m`.
    pub fn m(&self) -> f64 {
        if self.n_accepted == 0 {
            0.0
        } else {
            self.n_trials as f64 / self.n_accepted as f64
        }
    }
}

/// Integrate from `t0` to `t1` (either direction) starting from `state0`.
/// Returns the final state and stats; accepted steps stream to `obs`.
/// Thin wrapper over [`integrate_ws`] with a per-call workspace.
#[allow(clippy::too_many_arguments)]
pub fn integrate(
    solver: &dyn Solver,
    dynamics: &dyn Dynamics,
    t0: f64,
    t1: f64,
    state0: State,
    mode: &StepMode,
    norm: &ErrorNorm,
    obs: &mut dyn StepObserver,
) -> Result<(State, IntStats)> {
    integrate_obs(
        solver,
        dynamics,
        t0,
        t1,
        state0,
        mode,
        norm,
        &ObsGrid::none(),
        obs,
    )
}

/// [`integrate`] with an observation grid: the loop lands bitwise on
/// every `tᵢ` (see the module docs for the clamping rule) and fires
/// [`StepObserver::on_observation`] there.  With an empty grid this *is*
/// `integrate` — same decisions, same arithmetic.  Thin wrapper over
/// [`integrate_obs_ws`] with a per-call workspace.
#[allow(clippy::too_many_arguments)]
pub fn integrate_obs(
    solver: &dyn Solver,
    dynamics: &dyn Dynamics,
    t0: f64,
    t1: f64,
    state0: State,
    mode: &StepMode,
    norm: &ErrorNorm,
    grid: &ObsGrid,
    obs: &mut dyn StepObserver,
) -> Result<(State, IntStats)> {
    let mut ws = SolverWorkspace::new();
    let stats = integrate_obs_ws(
        solver, dynamics, t0, t1, &state0, mode, norm, grid, obs, &mut ws,
    )?;
    Ok((ws.take_output(), stats))
}

/// [`integrate_obs_ws`]'s observation-grid-free shape: borrow every loop
/// buffer from `ws`, leave the final state in
/// [`SolverWorkspace::output`], return only the stats.
#[allow(clippy::too_many_arguments)]
pub fn integrate_ws(
    solver: &dyn Solver,
    dynamics: &dyn Dynamics,
    t0: f64,
    t1: f64,
    state0: &State,
    mode: &StepMode,
    norm: &ErrorNorm,
    obs: &mut dyn StepObserver,
    ws: &mut SolverWorkspace,
) -> Result<IntStats> {
    integrate_obs_ws(
        solver,
        dynamics,
        t0,
        t1,
        state0,
        mode,
        norm,
        &ObsGrid::none(),
        obs,
        ws,
    )
}

/// The workspace-path integration loop: identical decisions and
/// arithmetic to [`integrate_obs`] (which wraps it), but every loop
/// buffer — the ping-ponged current/next states, the error vector, the
/// solver's stage scratch — is borrowed from `ws`, so after warm-up one
/// accepted step performs **zero** heap allocations (given a solver and
/// dynamics with in-place `_into` paths; asserted by
/// `tests/alloc_steady.rs`).  The final state is left in
/// [`SolverWorkspace::output`].
#[allow(clippy::too_many_arguments)]
pub fn integrate_obs_ws(
    solver: &dyn Solver,
    dynamics: &dyn Dynamics,
    t0: f64,
    t1: f64,
    state0: &State,
    mode: &StepMode,
    norm: &ErrorNorm,
    grid: &ObsGrid,
    obs: &mut dyn StepObserver,
    ws: &mut SolverWorkspace,
) -> Result<IntStats> {
    let span = t1 - t0;
    if span == 0.0 {
        ensure!(
            grid.is_empty(),
            "zero-span integration cannot reach observation times"
        );
        let s = ws.take_state_copy(state0);
        ws.set_output(s);
        return Ok(IntStats::default());
    }
    grid.validate_for(t0, t1)?;
    let dir = span.signum();
    let f0 = dynamics.counters().f_evals.get();
    let mut stats = IntStats::default();
    let mut state = ws.take_state_copy(state0);
    let mut next = ws.take_state(state0);
    let mut err = ws.take_err();
    let mut t = t0;
    let k_total = grid.len();

    match *mode {
        StepMode::Fixed { h } => {
            if h <= 0.0 {
                bail!("fixed step size must be positive, got {h}");
            }
            // Split the span at the observation times (plus a trailing
            // segment to t1 unless the last observation IS t1): n equal
            // steps of |h'| ≤ h per segment — with an empty grid this is
            // the one-segment grid the plain loop always used.
            let mut t_seg = t0;
            for seg in 0..=k_total {
                if seg == k_total && k_total > 0 && grid.time(k_total - 1) == t1 {
                    break;
                }
                let seg_end = if seg < k_total { grid.time(seg) } else { t1 };
                let n = ((seg_end - t_seg).abs() / h).ceil().max(1.0) as usize;
                let hs = (seg_end - t_seg) / n as f64;
                for i in 0..n {
                    let _ = solver.step_into(dynamics, t, hs, &state, &mut next, &mut err, ws);
                    obs.on_trial(t, hs, next.bytes(), true);
                    let t_end = if i + 1 == n { seg_end } else { t + hs };
                    obs.on_accept(&AcceptedStep {
                        index: stats.n_accepted,
                        t,
                        h: hs,
                        t_end,
                        before: &state,
                        after: &next,
                        trials: 1,
                    });
                    std::mem::swap(&mut state, &mut next);
                    t = t_end;
                    stats.n_accepted += 1;
                    stats.n_trials += 1;
                }
                t_seg = seg_end;
                if seg < k_total {
                    obs.on_observation(seg, t, &state);
                }
            }
        }
        StepMode::Adaptive {
            rtol,
            atol,
            h_init,
            h_min,
            h_max,
        } => {
            if !solver.has_error_estimate() {
                bail!(
                    "solver '{}' has no embedded error estimate; use StepMode::Fixed",
                    solver.name()
                );
            }
            let p = solver.order() as f64;
            let mut h = h_init.abs().min(h_max).max(h_min) * dir;
            let eps = 1e-12 * span.abs().max(1.0);
            let mut next_obs = 0usize;
            while (t1 - t) * dir > eps {
                // fire observations the previous step happened to end on
                // exactly (without having been clamped to them)
                while next_obs < k_total && grid.time(next_obs) == t {
                    obs.on_observation(next_obs, t, &state);
                    next_obs += 1;
                }
                // clamp to the nearest barrier: the next unvisited
                // observation, else the endpoint
                let target = if next_obs < k_total {
                    grid.time(next_obs)
                } else {
                    t1
                };
                let mut aimed = false;
                let h_free = h;
                if (t + h - target) * dir > 0.0 {
                    h = target - t;
                    aimed = true;
                }
                let mut trials = 0usize;
                loop {
                    trials += 1;
                    stats.n_trials += 1;
                    let has_err =
                        solver.step_into(dynamics, t, h, &state, &mut next, &mut err, ws);
                    let en = norm.eval(
                        if has_err { &err } else { &[] },
                        &state.z,
                        &next.z,
                        rtol,
                        atol,
                    );
                    obs.on_trial(t, h, next.bytes(), en <= 1.0);
                    let at_floor = h.abs() <= h_min * 1.0000001;
                    if en <= 1.0 || at_floor {
                        // accept; a step that aimed at a barrier lands on
                        // it bitwise
                        let t_end = if aimed { target } else { t + h };
                        obs.on_accept(&AcceptedStep {
                            index: stats.n_accepted,
                            t,
                            h,
                            t_end,
                            before: &state,
                            after: &next,
                            trials,
                        });
                        std::mem::swap(&mut state, &mut next);
                        t = t_end;
                        stats.n_accepted += 1;
                        if aimed && next_obs < k_total {
                            obs.on_observation(next_obs, t, &state);
                            next_obs += 1;
                        }
                        // grow for the next step (Hairer's controller)
                        let factor = if en > 0.0 {
                            (0.9 * en.powf(-1.0 / p)).clamp(0.2, 10.0)
                        } else {
                            10.0
                        };
                        h = (h.abs() * factor).clamp(h_min, h_max) * dir;
                        // A barrier-clamped step is an output-point
                        // artifact, not an error-control decision: restore
                        // the controller's pre-clamp step so its memory
                        // survives every observation (standard output-point
                        // handling; with an empty grid the only clamp is
                        // the final one, so decisions are unchanged).
                        if aimed && h_free.abs() > h.abs() {
                            h = h_free;
                        }
                        break;
                    }
                    // reject: shrink (paper's DecayFactor with the standard
                    // error-proportional rule); a shrunken step no longer
                    // lands on the barrier
                    let factor = (0.9 * en.powf(-1.0 / p)).clamp(0.2, 0.9);
                    h = (h.abs() * factor).max(h_min) * dir;
                    aimed = false;
                    if trials > 60 {
                        bail!(
                            "step-size search did not converge at t={t} (h={h}, err={en})"
                        );
                    }
                }
            }
            // an observation may coincide with the final accepted time
            while next_obs < k_total && grid.time(next_obs) == t {
                obs.on_observation(next_obs, t, &state);
                next_obs += 1;
            }
            ensure!(
                next_obs == k_total,
                "adaptive integration terminated at t = {t} before reaching \
                 observation time {} (span {t0} → {t1} too short?)",
                grid.time(next_obs.min(k_total - 1))
            );
        }
    }
    stats.f_evals = dynamics.counters().f_evals.get() - f0;
    ws.put_state(next);
    ws.put_err(err);
    ws.set_output(state);
    Ok(stats)
}

// ---------------------------------------------------------------------------
// Resumable integration: warm per-session state across incremental advances.
// ---------------------------------------------------------------------------

/// Warm, resumable integration state: everything the stepping loop carries
/// between accepted steps, frozen at an observation barrier so the next
/// [`integrate_obs_resume_ws`] call continues **bitwise** where a one-shot
/// [`integrate_obs_ws`] over the concatenated grid would be.
///
/// Carried across advances: the barrier time `t`, the solver state (`z`,
/// and `v` for ALF — computed once at the first advance, never
/// re-initialized), the step-size controller's signed step `h` (including
/// the pre-clamp restore after a barrier landing, so controller memory
/// survives the advance boundary exactly as it survives an observation
/// inside one solve), and the integration direction.
///
/// ### Resume-boundary semantics
///
/// A one-shot [`ObsGrid`] lives in the half-open span `(t0, t1]` — an
/// observation bitwise-equal to `t0` is rejected by
/// [`ObsGrid::validate_for`], and a naive "re-solve from the barrier"
/// session would either silently drop such an event or deliver the
/// barrier observation twice.  A resumed advance instead admits a
/// **leading** event time bitwise-equal to the resume point `t`:
///
/// * if nothing has been delivered at `t` yet (a fresh session at `t0`),
///   it fires immediately with the current state — exactly once;
/// * if the previous advance already delivered an observation at `t`
///   (every successful advance ends on its final observation), a leading
///   duplicate is an **error**, never a silent skip or a double fire.
///
/// Every successful advance ends at its last event time, which is always
/// an observation — so the concatenation of the per-advance event lists
/// (without boundary duplicates) is exactly the one-shot grid, and final
/// state, per-observation snapshots and step/trial counts are
/// bitwise-identical to the one-shot solve.  The only divergence is where
/// the one-shot loop *errors*: its termination test can strand an
/// unclamped landing within `eps` of the final observation, while the
/// resumable loop terminates on observation delivery and has no such
/// failure mode.
#[derive(Debug, Clone)]
pub struct ResumeState {
    /// Last accepted time — the previous advance's final observation
    /// barrier (or `t0` before the first advance).
    t: f64,
    /// Carried solver state at `t` (plain `z` until the first advance
    /// initializes the solver, then augmented per the solver).
    state: State,
    /// The step-size controller's signed next step; `0.0` until the first
    /// adaptive advance seeds it from `h_init`.
    h: f64,
    /// Integration direction (`±1.0`); `0.0` until the first advance with
    /// a target beyond `t` fixes it.
    dir: f64,
    /// Whether `Solver::init` has run (lazily, at the first advance).
    started: bool,
    /// Whether an observation has already been delivered at exactly `t` —
    /// the resume-boundary bookkeeping described above.
    fired_at_t: bool,
}

impl ResumeState {
    /// A fresh session at `t0` with initial state `z0`.  The solver's
    /// augmented state (ALF's `v₀ = f(z₀)`) is built lazily by the first
    /// advance, so constructing a session costs nothing.
    pub fn new(t0: f64, z0: Vec<f32>) -> ResumeState {
        ResumeState {
            t: t0,
            state: State::from_z(z0),
            h: 0.0,
            dir: 0.0,
            started: false,
            fired_at_t: false,
        }
    }

    /// Current barrier time.
    pub fn t(&self) -> f64 {
        self.t
    }

    /// Current state `z(t)`.
    pub fn z(&self) -> &[f32] {
        &self.state.z
    }

    /// Current (possibly augmented) solver state.
    pub fn state(&self) -> &State {
        &self.state
    }

    /// Whether an observation has already been delivered at exactly
    /// [`ResumeState::t`].
    pub fn fired_at_t(&self) -> bool {
        self.fired_at_t
    }
}

/// Advance a resumable integration to each event time in `times`, firing
/// [`StepObserver::on_observation`] at every one (indexed by position in
/// `times`) — the incremental form of [`integrate_obs_ws`].
///
/// `times` must be finite, strictly monotone along the session's
/// integration direction, and strictly beyond the resume point — except
/// that a *leading* time bitwise-equal to `rs.t()` is delivered as a
/// snapshot of the current state (see [`ResumeState`] for the boundary
/// rule).  The advance always ends at the last event time.
///
/// Callers must pass the same `solver`, `dynamics`, `mode` and `norm` on
/// every advance of one session; the loop's per-step decisions are then
/// bitwise-identical to a one-shot solve over the concatenated grid.
/// On error the carried state is left at the last successful barrier and
/// the advance's partial observations must be discarded by the caller.
#[allow(clippy::too_many_arguments)]
pub fn integrate_obs_resume_ws(
    solver: &dyn Solver,
    dynamics: &dyn Dynamics,
    rs: &mut ResumeState,
    times: &[f64],
    mode: &StepMode,
    norm: &ErrorNorm,
    obs: &mut dyn StepObserver,
    ws: &mut SolverWorkspace,
) -> Result<IntStats> {
    ensure!(!times.is_empty(), "resumed advance needs at least one event time");
    for (k, &tk) in times.iter().enumerate() {
        ensure!(tk.is_finite(), "event time t[{k}] = {tk} is not finite");
    }

    // Resume-boundary rule: a leading event at exactly the barrier is a
    // snapshot request, valid only if the barrier observation has not been
    // delivered yet.
    let lead = if times[0] == rs.t {
        ensure!(
            !rs.fired_at_t,
            "observation at t = {} was already delivered at the resume barrier; \
             event times must be strictly beyond the last delivered observation",
            rs.t
        );
        1
    } else {
        0
    };

    // Direction and strict monotonicity beyond the resume point.
    let mut dir = rs.dir;
    if lead < times.len() {
        let d = (times[lead] - rs.t).signum();
        ensure!(
            d != 0.0,
            "event time t[{lead}] = {} duplicates the resume point {}",
            times[lead],
            rs.t
        );
        if dir == 0.0 {
            dir = d;
        }
        ensure!(
            d == dir,
            "event time t[{lead}] = {} runs against the session's integration \
             direction (resume point {}, dir {dir})",
            times[lead],
            rs.t
        );
        if let Some(w) = times[lead..].windows(2).find(|w| (w[1] - w[0]) * dir <= 0.0) {
            bail!(
                "event times {w:?} not strictly ordered in the integration \
                 direction (dir {dir})"
            );
        }
    }

    // Lazy solver init: build the augmented state (ALF's v₀ = f(z₀)) once,
    // exactly as a one-shot caller does before integrating.
    if !rs.started {
        let z0 = std::mem::take(&mut rs.state.z);
        rs.state = solver.init(dynamics, rs.t, &z0);
        rs.started = true;
    }

    // Deliver the leading barrier snapshot (exactly once per session).
    if lead == 1 {
        obs.on_observation(0, rs.t, &rs.state);
        rs.fired_at_t = true;
        if times.len() == 1 {
            return Ok(IntStats::default());
        }
    }

    let f0 = dynamics.counters().f_evals.get();
    let mut stats = IntStats::default();
    let mut state = ws.take_state_copy(&rs.state);
    let mut next = ws.take_state(&rs.state);
    let mut err = ws.take_err();
    let mut t = rs.t;
    let k_total = times.len();
    let mut h_carry = rs.h;

    match *mode {
        StepMode::Fixed { h } => {
            if h <= 0.0 {
                bail!("fixed step size must be positive, got {h}");
            }
            // Identical segment arithmetic to the one-shot fixed loop: the
            // span is split at the event times and each segment takes n
            // equal steps of |h'| ≤ h — segment decisions depend only on
            // the segment endpoints, so resuming at a barrier is exact.
            let mut t_seg = t;
            for seg in lead..k_total {
                let seg_end = times[seg];
                let n = ((seg_end - t_seg).abs() / h).ceil().max(1.0) as usize;
                let hs = (seg_end - t_seg) / n as f64;
                for i in 0..n {
                    let _ = solver.step_into(dynamics, t, hs, &state, &mut next, &mut err, ws);
                    obs.on_trial(t, hs, next.bytes(), true);
                    let t_end = if i + 1 == n { seg_end } else { t + hs };
                    obs.on_accept(&AcceptedStep {
                        index: stats.n_accepted,
                        t,
                        h: hs,
                        t_end,
                        before: &state,
                        after: &next,
                        trials: 1,
                    });
                    std::mem::swap(&mut state, &mut next);
                    t = t_end;
                    stats.n_accepted += 1;
                    stats.n_trials += 1;
                }
                t_seg = seg_end;
                obs.on_observation(seg, t, &state);
            }
        }
        StepMode::Adaptive {
            rtol,
            atol,
            h_init,
            h_min,
            h_max,
        } => {
            if !solver.has_error_estimate() {
                bail!(
                    "solver '{}' has no embedded error estimate; use StepMode::Fixed",
                    solver.name()
                );
            }
            let p = solver.order() as f64;
            // Controller memory: first advance seeds from h_init exactly
            // like the one-shot loop; later advances continue with the
            // carried step, which is what the one-shot loop would hold
            // after its barrier landing at this t.
            let mut h = if h_carry == 0.0 {
                h_init.abs().min(h_max).max(h_min) * dir
            } else {
                h_carry
            };
            let mut next_obs = lead;
            // Terminate on observation delivery instead of the one-shot's
            // eps test against t1: every advance ends at its final event
            // time, and all earlier decisions are target-relative, so the
            // two loops take bitwise-identical steps.
            while next_obs < k_total {
                // fire observations the previous step happened to end on
                // exactly (without having been clamped to them)
                while next_obs < k_total && times[next_obs] == t {
                    obs.on_observation(next_obs, t, &state);
                    next_obs += 1;
                }
                if next_obs >= k_total {
                    break;
                }
                let target = times[next_obs];
                let mut aimed = false;
                let h_free = h;
                if (t + h - target) * dir > 0.0 {
                    h = target - t;
                    aimed = true;
                }
                let mut trials = 0usize;
                loop {
                    trials += 1;
                    stats.n_trials += 1;
                    let has_err =
                        solver.step_into(dynamics, t, h, &state, &mut next, &mut err, ws);
                    let en = norm.eval(
                        if has_err { &err } else { &[] },
                        &state.z,
                        &next.z,
                        rtol,
                        atol,
                    );
                    obs.on_trial(t, h, next.bytes(), en <= 1.0);
                    let at_floor = h.abs() <= h_min * 1.0000001;
                    if en <= 1.0 || at_floor {
                        // accept; a step that aimed at a barrier lands on
                        // it bitwise
                        let t_end = if aimed { target } else { t + h };
                        obs.on_accept(&AcceptedStep {
                            index: stats.n_accepted,
                            t,
                            h,
                            t_end,
                            before: &state,
                            after: &next,
                            trials,
                        });
                        std::mem::swap(&mut state, &mut next);
                        t = t_end;
                        stats.n_accepted += 1;
                        if aimed && next_obs < k_total {
                            obs.on_observation(next_obs, t, &state);
                            next_obs += 1;
                        }
                        // grow for the next step (Hairer's controller)
                        let factor = if en > 0.0 {
                            (0.9 * en.powf(-1.0 / p)).clamp(0.2, 10.0)
                        } else {
                            10.0
                        };
                        h = (h.abs() * factor).clamp(h_min, h_max) * dir;
                        // restore the controller's pre-clamp step across a
                        // barrier landing (same output-point handling as
                        // the one-shot loop)
                        if aimed && h_free.abs() > h.abs() {
                            h = h_free;
                        }
                        break;
                    }
                    // reject: shrink; a shrunken step no longer lands on
                    // the barrier
                    let factor = (0.9 * en.powf(-1.0 / p)).clamp(0.2, 0.9);
                    h = (h.abs() * factor).max(h_min) * dir;
                    aimed = false;
                    if trials > 60 {
                        bail!(
                            "step-size search did not converge at t={t} (h={h}, err={en})"
                        );
                    }
                }
            }
            h_carry = h;
        }
    }

    stats.f_evals = dynamics.counters().f_evals.get() - f0;
    // Commit: the advance ended on its final observation barrier.
    rs.t = t;
    rs.dir = dir;
    rs.h = h_carry;
    rs.fired_at_t = true;
    rs.state.z.copy_from_slice(&state.z);
    match (&mut rs.state.v, &state.v) {
        (Some(dst), Some(src)) => dst.copy_from_slice(src),
        (None, None) => {}
        // unreachable in practice (the loop buffers share rs.state's
        // v-ness), but stay value-correct rather than assert
        (dst, src) => *dst = src.clone(),
    }
    ws.put_state(state);
    ws.put_state(next);
    ws.put_err(err);
    Ok(stats)
}

// ---------------------------------------------------------------------------
// Batch-first integration: per-sample step control with an active mask.
// ---------------------------------------------------------------------------

/// One accepted step of one sample inside a batched integration, seen by
/// [`BatchStepObserver`]s.  Rows are borrowed from the batch buffers —
/// observers copy only what they retain (checkpoints, tapes).
pub struct BatchAcceptedStep<'a> {
    /// Which sample (batch row) this step belongs to.
    pub sample: usize,
    /// Per-sample accepted-step index.
    pub index: usize,
    /// Step start time and (signed) size; the step ends at `t_end`.
    pub t: f64,
    pub h: f64,
    /// Exact end time of the step: `t + h`, except snapped bitwise onto
    /// the barrier (observation time or endpoint) the step was clamped to.
    pub t_end: f64,
    pub before_z: &'a [f32],
    pub before_v: Option<&'a [f32]>,
    pub after_z: &'a [f32],
    pub after_v: Option<&'a [f32]>,
    /// Inner-loop iterations this sample spent on this step.
    pub trials: usize,
}

impl BatchAcceptedStep<'_> {
    /// The step's input state as an owned single-sample [`State`].
    pub fn before_state(&self) -> State {
        State {
            z: self.before_z.to_vec(),
            v: self.before_v.map(|v| v.to_vec()),
        }
    }
}

/// Observer for [`integrate_batch`]; like [`StepObserver`] but per sample.
pub trait BatchStepObserver {
    fn on_accept(&mut self, _step: &BatchAcceptedStep) {}
    /// Every trial of one sample (accepted or rejected) with the row bytes
    /// it materialized.
    fn on_trial(&mut self, _sample: usize, _t: f64, _h: f64, _state_bytes: usize, _accepted: bool) {
    }
    /// Sample `sample` reached observation `k` of the [`ObsGrid`] — fired
    /// once per (sample, observation), in grid order per sample, with `t`
    /// bitwise equal to the grid time and the row slices its state there.
    fn on_observation(
        &mut self,
        _sample: usize,
        _k: usize,
        _t: f64,
        _z: &[f32],
        _v: Option<&[f32]>,
    ) {
    }
}

impl BatchStepObserver for () {}

/// Statistics of one batched integration run.
///
/// `per_sample[b]` carries the *structural* counts (accepted steps,
/// trials) of sample `b` — exactly what a solo run of that row would
/// report; `f_evals` is the total across the batch (per-sample `f`
/// attribution is not tracked, so `per_sample[b].f_evals` is 0).
#[derive(Debug, Clone, Default)]
pub struct BatchIntStats {
    pub per_sample: Vec<IntStats>,
    /// Total `f` evaluations across the batch (counter delta).
    pub f_evals: u64,
}

impl BatchIntStats {
    /// Total accepted steps across the batch.
    pub fn n_accepted_total(&self) -> usize {
        self.per_sample.iter().map(|s| s.n_accepted).sum()
    }

    /// Total trials across the batch.
    pub fn n_trials_total(&self) -> usize {
        self.per_sample.iter().map(|s| s.n_trials).sum()
    }

    /// Largest per-sample accepted-step count (the longest chain any
    /// gradient flows through).
    pub fn n_accepted_max(&self) -> usize {
        self.per_sample.iter().map(|s| s.n_accepted).max().unwrap_or(0)
    }

    /// Batch-aggregated [`IntStats`] (sums; `m()` becomes the batch mean).
    pub fn aggregate(&self) -> IntStats {
        IntStats {
            n_accepted: self.n_accepted_total(),
            n_trials: self.n_trials_total(),
            f_evals: self.f_evals,
        }
    }
}

/// Integrate a batch of independent trajectories from `t0` to `t1`.
///
/// * `Fixed` mode steps all rows in lockstep on the shared grid — one
///   batched solver step (and thus one batched `f` per stage) per grid
///   point.
/// * `Adaptive` mode gives every sample its own step-size controller
///   (identical, decision-for-decision, to a solo [`integrate`] run of
///   that row) and keeps an **active mask**: rows that reached `t1` are
///   dropped from the gathered sub-batch, so early-converged samples stop
///   consuming `f` evaluations while stragglers finish.
///
/// A `Semi` error norm is applied per row and its mask must have length
/// `n_z` (one row width).
#[allow(clippy::too_many_arguments)]
pub fn integrate_batch(
    solver: &dyn Solver,
    dynamics: &dyn Dynamics,
    t0: f64,
    t1: f64,
    state0: BatchState,
    mode: &StepMode,
    norm: &ErrorNorm,
    obs: &mut dyn BatchStepObserver,
) -> Result<(BatchState, BatchIntStats)> {
    integrate_batch_obs(
        solver,
        dynamics,
        t0,
        t1,
        state0,
        mode,
        norm,
        &ObsGrid::none(),
        obs,
    )
}

/// [`integrate_batch`] with an observation grid shared by all rows: every
/// sample's controller lands bitwise on every `tᵢ` (per-row clamping,
/// decision-identical to a solo [`integrate_obs`] run of that row) and
/// fires [`BatchStepObserver::on_observation`] per (sample, observation).
/// Thin wrapper over [`integrate_batch_obs_ws`] with a per-call
/// workspace.
#[allow(clippy::too_many_arguments)]
pub fn integrate_batch_obs(
    solver: &dyn Solver,
    dynamics: &dyn Dynamics,
    t0: f64,
    t1: f64,
    state0: BatchState,
    mode: &StepMode,
    norm: &ErrorNorm,
    grid: &ObsGrid,
    obs: &mut dyn BatchStepObserver,
) -> Result<(BatchState, BatchIntStats)> {
    let mut ws = BatchWorkspace::new();
    let stats = integrate_batch_obs_ws(
        solver, dynamics, t0, t1, &state0, mode, norm, grid, obs, &mut ws,
    )?;
    Ok((ws.take_output(), stats))
}

/// [`integrate_batch_obs_ws`]'s observation-grid-free shape.
#[allow(clippy::too_many_arguments)]
pub fn integrate_batch_ws(
    solver: &dyn Solver,
    dynamics: &dyn Dynamics,
    t0: f64,
    t1: f64,
    state0: &BatchState,
    mode: &StepMode,
    norm: &ErrorNorm,
    obs: &mut dyn BatchStepObserver,
    ws: &mut BatchWorkspace,
) -> Result<BatchIntStats> {
    integrate_batch_obs_ws(
        solver,
        dynamics,
        t0,
        t1,
        state0,
        mode,
        norm,
        &ObsGrid::none(),
        obs,
        ws,
    )
}

/// The workspace-path batched integration loop: identical decisions and
/// arithmetic to [`integrate_batch_obs`] (which wraps it), but the
/// ping-ponged batch states, the error buffer, gathered sub-batches and
/// the solver's stage scratch are all borrowed from `ws`.  Thin wrapper
/// over [`integrate_batch_obs_stats_ws`] that allocates the returned
/// per-sample stats vector; hot serve/train loops that must stay
/// allocation-free call the `_stats_ws` entry point with a recycled
/// vector instead.  The final state is left in [`BatchWorkspace::output`].
#[allow(clippy::too_many_arguments)]
pub fn integrate_batch_obs_ws(
    solver: &dyn Solver,
    dynamics: &dyn Dynamics,
    t0: f64,
    t1: f64,
    state0: &BatchState,
    mode: &StepMode,
    norm: &ErrorNorm,
    grid: &ObsGrid,
    obs: &mut dyn BatchStepObserver,
    ws: &mut BatchWorkspace,
) -> Result<BatchIntStats> {
    let mut per = Vec::new();
    let f_evals = integrate_batch_obs_stats_ws(
        solver, dynamics, t0, t1, state0, mode, norm, grid, obs, &mut per, ws,
    )?;
    Ok(BatchIntStats {
        per_sample: per,
        f_evals,
    })
}

/// Per-sample controller scratch of the batched loop, `mem::take`n out of
/// the [`BatchWorkspace`] for the duration of a run (the loop passes
/// `&mut ws` to the solver, so these buffers cannot stay behind that
/// borrow) and restored afterwards — including on error paths, so a
/// failed solve does not forfeit the warmed capacities.
struct CtrlScratch {
    ts_row: Vec<f64>,
    hs_row: Vec<f64>,
    t_cur: Vec<f64>,
    h_cur: Vec<f64>,
    h_free: Vec<f64>,
    trials_cur: Vec<usize>,
    accepted_idx: Vec<usize>,
    next_obs_row: Vec<usize>,
    aimed: Vec<bool>,
    active: Vec<usize>,
    still: Vec<usize>,
}

impl CtrlScratch {
    fn take(ws: &mut BatchWorkspace) -> CtrlScratch {
        CtrlScratch {
            ts_row: std::mem::take(&mut ws.ts_row),
            hs_row: std::mem::take(&mut ws.hs_row),
            t_cur: std::mem::take(&mut ws.t_cur),
            h_cur: std::mem::take(&mut ws.h_cur),
            h_free: std::mem::take(&mut ws.h_free),
            trials_cur: std::mem::take(&mut ws.trials_cur),
            accepted_idx: std::mem::take(&mut ws.accepted_idx),
            next_obs_row: std::mem::take(&mut ws.next_obs_row),
            aimed: std::mem::take(&mut ws.aimed),
            active: std::mem::take(&mut ws.active),
            still: std::mem::take(&mut ws.still),
        }
    }

    fn restore(self, ws: &mut BatchWorkspace) {
        ws.ts_row = self.ts_row;
        ws.hs_row = self.hs_row;
        ws.t_cur = self.t_cur;
        ws.h_cur = self.h_cur;
        ws.h_free = self.h_free;
        ws.trials_cur = self.trials_cur;
        ws.accepted_idx = self.accepted_idx;
        ws.next_obs_row = self.next_obs_row;
        ws.aimed = self.aimed;
        ws.active = self.active;
        ws.still = self.still;
    }
}

/// [`integrate_batch_obs_ws`] with the per-sample stats written into a
/// caller-recycled vector (`per` is cleared and refilled; capacity is
/// reused) instead of a freshly allocated [`BatchIntStats`].  Returns the
/// batch `f`-evaluation total.
///
/// This is the fully pooled shape of the batched loop: the ping-ponged
/// batch states, gathered sub-batches, the error buffer, the solver's
/// stage scratch **and** the per-sample step-size-controller state
/// (current times/steps, trial counts, barrier flags, the active mask)
/// all come from `ws`, so a warmed call with stable shapes performs
/// **zero** heap allocations in fixed mode, and in adaptive mode as long
/// as the rows stay in lockstep (`tests/alloc_serve.rs` pins both for
/// the serving loop).  Decisions and arithmetic are bit-identical to
/// [`integrate_batch_obs`] by construction.
#[allow(clippy::too_many_arguments)]
pub fn integrate_batch_obs_stats_ws(
    solver: &dyn Solver,
    dynamics: &dyn Dynamics,
    t0: f64,
    t1: f64,
    state0: &BatchState,
    mode: &StepMode,
    norm: &ErrorNorm,
    grid: &ObsGrid,
    obs: &mut dyn BatchStepObserver,
    per: &mut Vec<IntStats>,
    ws: &mut BatchWorkspace,
) -> Result<u64> {
    let spec = state0.spec();
    let nb = spec.batch;
    let span = t1 - t0;
    let f0 = dynamics.counters().f_evals.get();
    per.clear();
    per.resize(nb, IntStats::default());
    if span == 0.0 {
        ensure!(
            grid.is_empty(),
            "zero-span integration cannot reach observation times"
        );
        let s = ws.take_batch_copy(state0);
        ws.set_output(s);
        return Ok(0);
    }
    grid.validate_for(t0, t1)?;
    let mut c = CtrlScratch::take(ws);
    let r = batched_obs_loop(
        solver, dynamics, t0, t1, state0, mode, norm, grid, obs, per, &mut c, ws,
    );
    c.restore(ws);
    r?;
    Ok(dynamics.counters().f_evals.get() - f0)
}

/// Per-shard persistent resources of the intra-batch sharded driver
/// ([`integrate_batch_obs_stats_sharded`]): each shard owns a full
/// [`BatchWorkspace`], a per-sample stats vector and a sub-batch `state0`
/// buffer, so a warmed sharded solve touches the allocator exactly as much
/// as `shards` warmed unsharded solves — zero times
/// (`tests/alloc_serve.rs` / `tests/alloc_steady.rs`).
pub struct BatchShards {
    slots: Vec<ShardSlot>,
}

struct ShardSlot {
    /// Global `[start, end)` row range of this shard (set per dispatch).
    range: (usize, usize),
    state0: BatchState,
    ws: BatchWorkspace,
    per: Vec<IntStats>,
    err: Option<anyhow::Error>,
}

impl BatchShards {
    /// Resources for `shards` row-range shards (clamped to at least 1).
    pub fn new(shards: usize) -> BatchShards {
        BatchShards {
            slots: (0..shards.max(1))
                .map(|_| ShardSlot {
                    range: (0, 0),
                    state0: BatchState {
                        z: crate::tensor::Tensor {
                            data: Vec::new(),
                            shape: vec![0, 0],
                        },
                        v: None,
                    },
                    ws: BatchWorkspace::new(),
                    per: Vec::new(),
                    err: None,
                })
                .collect(),
        }
    }

    /// Number of shards these resources support.
    pub fn count(&self) -> usize {
        self.slots.len()
    }
}

/// Intra-batch sharded [`integrate_batch_obs_stats_ws`]: splits the
/// `[B, N_z]` batch into contiguous row-range shards
/// ([`crate::util::pool::shard_ranges`]) and integrates each shard as an
/// independent sub-batch, optionally in parallel on a persistent
/// [`WorkerPool`] (the dispatching thread participates; `pool: None` or a
/// 0-thread pool runs the shards sequentially in shard order).
///
/// **Bitwise contract** (pinned by `tests/shard_equivalence.rs`): the
/// result — final states, per-observation snapshots, per-sample
/// accepted/trial counts and the `f`-evaluation total — is identical to
/// the 1-shard run for any shard count.  This holds because the batched
/// loop's per-row work is already row-decomposable: each sample owns its
/// step-size controller, and a native dynamics' batched `f` is the
/// row-wise map of its solo `f` (pinned by `tests/batch_equivalence.rs`),
/// so integrating a sub-range of rows performs the exact same per-row
/// arithmetic in the exact same order.  Device-batched dynamics (one
/// compiled XLA batch program, `B` baked into the executable) are
/// rejected when `shards > 1`.
///
/// `make_obs(shard, rows)` builds each shard's observer **on the thread
/// that runs the shard**, with `rows` the global row range; observer
/// callbacks receive shard-local sample indices (add `rows.start` to
/// globalize).  Per-shard workspaces and stats live in `shards` and stay
/// warm across calls; `per` receives the merged per-sample stats in
/// global row order.  Returns the batch `f`-evaluation total, measured as
/// one counter-window delta around the whole dispatch (per-shard deltas
/// interleave under concurrency).
#[allow(clippy::too_many_arguments)]
pub fn integrate_batch_obs_stats_sharded<O, F>(
    solver: &(dyn Solver + Sync),
    dynamics: &(dyn Dynamics + Sync),
    t0: f64,
    t1: f64,
    state0: &BatchState,
    mode: &StepMode,
    norm: &ErrorNorm,
    grid: &ObsGrid,
    make_obs: F,
    per: &mut Vec<IntStats>,
    shards: &mut BatchShards,
    ws: &mut BatchWorkspace,
    pool: Option<&WorkerPool>,
) -> Result<u64>
where
    O: BatchStepObserver,
    F: Fn(usize, std::ops::Range<usize>) -> O + Sync,
{
    let spec = state0.spec();
    let nb = spec.batch;
    let n_z = spec.n_z;
    let has_v = state0.v.is_some();
    let n_shards = shards.slots.len();
    if n_shards <= 1 || nb <= 1 || t1 - t0 == 0.0 {
        // Degenerate split: the sharded path *is* the direct path.
        let mut obs = make_obs(0, 0..nb);
        return integrate_batch_obs_stats_ws(
            solver, dynamics, t0, t1, state0, mode, norm, grid, &mut obs, per, ws,
        );
    }
    ensure!(
        !dynamics.is_device_batched(),
        "intra-batch sharding requires row-decomposable dynamics; this \
         dynamics is device-batched (the batch dimension is baked into one \
         XLA executable, so sub-batches cannot reuse it)"
    );

    // Stage each shard's sub-batch initial state (contiguous row block —
    // one copy_from_slice per buffer; all shard buffers grow once and
    // stay warm).
    for (slot, (r0, r1)) in shards
        .slots
        .iter_mut()
        .zip(crate::util::pool::shard_ranges(nb, n_shards))
    {
        slot.range = (r0, r1);
        slot.err = None;
        if r1 > r0 {
            super::workspace::shape_batch_state(&mut slot.state0, r1 - r0, n_z, has_v);
            slot.state0
                .z
                .data
                .copy_from_slice(&state0.z.data[r0 * n_z..r1 * n_z]);
            if let (Some(dv), Some(sv)) = (&mut slot.state0.v, &state0.v) {
                dv.data.copy_from_slice(&sv.data[r0 * n_z..r1 * n_z]);
            }
        }
    }

    let f0 = dynamics.counters().f_evals.get();
    let view = DisjointRowsMut::new(&mut shards.slots);
    let body = |i: usize| {
        // SAFETY: every job index is dispatched exactly once per run, so
        // the 1-slot ranges are pairwise disjoint and end before `view`'s
        // source borrow does (the dispatch joins below).
        let slot = &mut unsafe { view.range(i, i + 1) }[0];
        let (r0, r1) = slot.range;
        slot.per.clear();
        if r1 == r0 {
            // empty shard (shards > B): nothing to integrate
            return;
        }
        let mut obs = make_obs(i, r0..r1);
        if let Err(e) = integrate_batch_obs_stats_ws(
            solver,
            dynamics,
            t0,
            t1,
            &slot.state0,
            mode,
            norm,
            grid,
            &mut obs,
            &mut slot.per,
            &mut slot.ws,
        ) {
            slot.err = Some(e);
        }
    };
    match pool {
        Some(p) => p.run(n_shards, &body),
        None => {
            for i in 0..n_shards {
                body(i);
            }
        }
    }
    let f_evals = dynamics.counters().f_evals.get() - f0;

    for slot in &mut shards.slots {
        if let Some(e) = slot.err.take() {
            return Err(e);
        }
    }

    // Merge: per-sample stats in global row order, final states assembled
    // row-contiguously into this workspace's output slot.
    per.clear();
    let mut out = ws.take_batch(nb, n_z, has_v);
    for slot in &shards.slots {
        let (r0, r1) = slot.range;
        per.extend_from_slice(&slot.per);
        if r1 > r0 {
            let shard_out = slot.ws.output();
            out.z.data[r0 * n_z..r1 * n_z].copy_from_slice(&shard_out.z.data);
            if let (Some(dv), Some(sv)) = (&mut out.v, &shard_out.v) {
                dv.data[r0 * n_z..r1 * n_z].copy_from_slice(&sv.data);
            }
        }
    }
    ws.set_output(out);
    Ok(f_evals)
}

/// The batched loop body behind [`integrate_batch_obs_stats_ws`];
/// separated so the [`CtrlScratch`] take/restore pair brackets every
/// return path.
#[allow(clippy::too_many_arguments)]
fn batched_obs_loop(
    solver: &dyn Solver,
    dynamics: &dyn Dynamics,
    t0: f64,
    t1: f64,
    state0: &BatchState,
    mode: &StepMode,
    norm: &ErrorNorm,
    grid: &ObsGrid,
    obs: &mut dyn BatchStepObserver,
    per: &mut [IntStats],
    c: &mut CtrlScratch,
    ws: &mut BatchWorkspace,
) -> Result<()> {
    let spec = state0.spec();
    let nb = spec.batch;
    let has_v = state0.v.is_some();
    let span = t1 - t0;
    let dir = span.signum();
    let k_total = grid.len();
    let mut state = ws.take_batch_copy(state0);

    match *mode {
        StepMode::Fixed { h } => {
            if h <= 0.0 {
                bail!("fixed step size must be positive, got {h}");
            }
            // lockstep segments between observation times (see the solo
            // loop): all rows share the grid, so one batched solver step
            // per grid point and one observation sweep per segment end
            super::workspace::ensure_f64(&mut c.hs_row, nb);
            super::workspace::ensure_f64(&mut c.ts_row, nb);
            let mut next = ws.take_batch(nb, spec.n_z, has_v);
            let mut err = ws.take_err();
            let mut index = 0usize;
            let mut t = t0;
            let mut t_seg = t0;
            for seg in 0..=k_total {
                if seg == k_total && k_total > 0 && grid.time(k_total - 1) == t1 {
                    break;
                }
                let seg_end = if seg < k_total { grid.time(seg) } else { t1 };
                let n = ((seg_end - t_seg).abs() / h).ceil().max(1.0) as usize;
                let hs = (seg_end - t_seg) / n as f64;
                c.hs_row.fill(hs);
                for i in 0..n {
                    c.ts_row.fill(t);
                    let _ = solver.step_batch_into(
                        dynamics, &c.ts_row, &c.hs_row, &state, &mut next, &mut err, ws,
                    );
                    let row_bytes = next.row_bytes();
                    let t_end = if i + 1 == n { seg_end } else { t + hs };
                    for (b, st) in per.iter_mut().enumerate() {
                        obs.on_trial(b, t, hs, row_bytes, true);
                        obs.on_accept(&BatchAcceptedStep {
                            sample: b,
                            index,
                            t,
                            h: hs,
                            t_end,
                            before_z: spec.row(&state.z.data, b),
                            before_v: state.v.as_ref().map(|v| spec.row(&v.data, b)),
                            after_z: spec.row(&next.z.data, b),
                            after_v: next.v.as_ref().map(|v| spec.row(&v.data, b)),
                            trials: 1,
                        });
                        st.n_accepted += 1;
                        st.n_trials += 1;
                    }
                    std::mem::swap(&mut state, &mut next);
                    t = t_end;
                    index += 1;
                }
                t_seg = seg_end;
                if seg < k_total {
                    for b in 0..nb {
                        obs.on_observation(
                            b,
                            seg,
                            t,
                            spec.row(&state.z.data, b),
                            state.v.as_ref().map(|v| spec.row(&v.data, b)),
                        );
                    }
                }
            }
            ws.put_batch(next);
            ws.put_err(err);
        }
        StepMode::Adaptive {
            rtol,
            atol,
            h_init,
            h_min,
            h_max,
        } => {
            if !solver.has_error_estimate() {
                bail!(
                    "solver '{}' has no embedded error estimate; use StepMode::Fixed",
                    solver.name()
                );
            }
            if let ErrorNorm::Semi(m) = norm {
                if m.len() != spec.n_z {
                    bail!(
                        "batched seminorm mask has length {}, want one row width {}",
                        m.len(),
                        spec.n_z
                    );
                }
            }
            let p = solver.order() as f64;
            let eps = 1e-12 * span.abs().max(1.0);
            let h0 = h_init.abs().min(h_max).max(h_min) * dir;
            // per-sample controller state — decision-identical to solo
            // runs; pooled in the workspace so a warmed batch re-solve
            // never touches the allocator
            use super::workspace::{ensure_f64, ensure_with};
            ensure_f64(&mut c.t_cur, nb);
            c.t_cur.fill(t0);
            let t_cur = &mut c.t_cur;
            ensure_f64(&mut c.h_cur, nb);
            c.h_cur.fill(h0);
            let h_cur = &mut c.h_cur;
            ensure_with(&mut c.trials_cur, nb, 0usize);
            c.trials_cur.fill(0);
            let trials_cur = &mut c.trials_cur;
            ensure_with(&mut c.accepted_idx, nb, 0usize);
            c.accepted_idx.fill(0);
            let accepted_idx = &mut c.accepted_idx;
            ensure_with(&mut c.next_obs_row, nb, 0usize);
            c.next_obs_row.fill(0);
            let next_obs = &mut c.next_obs_row;
            ensure_with(&mut c.aimed, nb, false);
            c.aimed.fill(false);
            let aimed = &mut c.aimed;
            ensure_f64(&mut c.h_free, nb);
            c.h_free.fill(h0);
            let h_free = &mut c.h_free;
            // same entry condition as the solo loop: a sub-eps span means
            // zero steps
            c.active.clear();
            if span.abs() > eps {
                c.active.extend(0..nb);
            }
            let active = &mut c.active;
            // reused across iterations (capacity stabilizes after the
            // first pass)
            c.ts_row.clear();
            c.hs_row.clear();
            c.still.clear();
            let ts = &mut c.ts_row;
            let hs = &mut c.hs_row;
            let still = &mut c.still;
            while !active.is_empty() {
                // rows opening a new step: fire exact-coincidence
                // observations, then clamp to the nearest barrier
                for &b in active.iter() {
                    if trials_cur[b] == 0 {
                        while next_obs[b] < k_total && grid.time(next_obs[b]) == t_cur[b] {
                            obs.on_observation(
                                b,
                                next_obs[b],
                                t_cur[b],
                                spec.row(&state.z.data, b),
                                state.v.as_ref().map(|v| spec.row(&v.data, b)),
                            );
                            next_obs[b] += 1;
                        }
                        let target = if next_obs[b] < k_total {
                            grid.time(next_obs[b])
                        } else {
                            t1
                        };
                        aimed[b] = false;
                        h_free[b] = h_cur[b];
                        if (t_cur[b] + h_cur[b] - target) * dir > 0.0 {
                            h_cur[b] = target - t_cur[b];
                            aimed[b] = true;
                        }
                    }
                }
                ts.clear();
                ts.extend(active.iter().map(|&b| t_cur[b]));
                hs.clear();
                hs.extend(active.iter().map(|&b| h_cur[b]));
                // skip the row gather while every sample is still active
                let mut next_sub = ws.take_batch(active.len(), spec.n_z, has_v);
                let mut err_sub = ws.take_err();
                let has_err = if active.len() == nb {
                    solver.step_batch_into(
                        dynamics, ts, hs, &state, &mut next_sub, &mut err_sub, ws,
                    )
                } else {
                    let mut sub = ws.take_batch(active.len(), spec.n_z, has_v);
                    for (k, &b) in active.iter().enumerate() {
                        sub.copy_row_from(k, &state, b);
                    }
                    let r = solver.step_batch_into(
                        dynamics, ts, hs, &sub, &mut next_sub, &mut err_sub, ws,
                    );
                    ws.put_batch(sub);
                    r
                };
                let sub_spec = next_sub.spec();
                let row_bytes = next_sub.row_bytes();
                still.clear();
                for (k, &b) in active.iter().enumerate() {
                    trials_cur[b] += 1;
                    per[b].n_trials += 1;
                    let err_row: &[f32] = if has_err { sub_spec.row(&err_sub, k) } else { &[] };
                    let en = norm.eval(
                        err_row,
                        spec.row(&state.z.data, b),
                        sub_spec.row(&next_sub.z.data, k),
                        rtol,
                        atol,
                    );
                    obs.on_trial(b, t_cur[b], h_cur[b], row_bytes, en <= 1.0);
                    let at_floor = h_cur[b].abs() <= h_min * 1.0000001;
                    if en <= 1.0 || at_floor {
                        // accept this sample's step; an aimed step lands
                        // bitwise on its barrier
                        let target = if next_obs[b] < k_total {
                            grid.time(next_obs[b])
                        } else {
                            t1
                        };
                        let t_end = if aimed[b] { target } else { t_cur[b] + h_cur[b] };
                        obs.on_accept(&BatchAcceptedStep {
                            sample: b,
                            index: accepted_idx[b],
                            t: t_cur[b],
                            h: h_cur[b],
                            t_end,
                            before_z: spec.row(&state.z.data, b),
                            before_v: state.v.as_ref().map(|v| spec.row(&v.data, b)),
                            after_z: sub_spec.row(&next_sub.z.data, k),
                            after_v: next_sub.v.as_ref().map(|v| sub_spec.row(&v.data, k)),
                            trials: trials_cur[b],
                        });
                        state.copy_row_from(b, &next_sub, k);
                        t_cur[b] = t_end;
                        per[b].n_accepted += 1;
                        accepted_idx[b] += 1;
                        if aimed[b] && next_obs[b] < k_total {
                            obs.on_observation(
                                b,
                                next_obs[b],
                                t_cur[b],
                                spec.row(&state.z.data, b),
                                state.v.as_ref().map(|v| spec.row(&v.data, b)),
                            );
                            next_obs[b] += 1;
                        }
                        // grow for the next step (Hairer's controller)
                        let factor = if en > 0.0 {
                            (0.9 * en.powf(-1.0 / p)).clamp(0.2, 10.0)
                        } else {
                            10.0
                        };
                        h_cur[b] = (h_cur[b].abs() * factor).clamp(h_min, h_max) * dir;
                        // restore the pre-clamp controller step after a
                        // barrier hit (see the solo loop)
                        if aimed[b] && h_free[b].abs() > h_cur[b].abs() {
                            h_cur[b] = h_free[b];
                        }
                        trials_cur[b] = 0;
                        if (t1 - t_cur[b]) * dir > eps {
                            still.push(b); // not there yet — stays active
                        }
                    } else {
                        // reject: shrink (same error-proportional rule as
                        // solo); the shrunken step no longer lands on the
                        // barrier
                        let factor = (0.9 * en.powf(-1.0 / p)).clamp(0.2, 0.9);
                        h_cur[b] = (h_cur[b].abs() * factor).max(h_min) * dir;
                        aimed[b] = false;
                        if trials_cur[b] > 60 {
                            bail!(
                                "step-size search did not converge for sample {b} at t={} (h={}, err={en})",
                                t_cur[b],
                                h_cur[b]
                            );
                        }
                        still.push(b);
                    }
                }
                ws.put_batch(next_sub);
                ws.put_err(err_sub);
                std::mem::swap(active, still);
            }
            // a row's final accepted time may coincide with an observation
            for b in 0..nb {
                while next_obs[b] < k_total && grid.time(next_obs[b]) == t_cur[b] {
                    obs.on_observation(
                        b,
                        next_obs[b],
                        t_cur[b],
                        spec.row(&state.z.data, b),
                        state.v.as_ref().map(|v| spec.row(&v.data, b)),
                    );
                    next_obs[b] += 1;
                }
                ensure!(
                    next_obs[b] == k_total,
                    "adaptive integration of sample {b} terminated at t = {} \
                     before reaching observation time {}",
                    t_cur[b],
                    grid.time(next_obs[b].min(k_total - 1))
                );
            }
        }
    }
    ws.set_output(state);
    Ok(())
}

/// Per-sample accepted-grid recorder — what batched MALI keeps from the
/// forward pass (paper Algo. 4, one grid per sample) plus the observation
/// bookkeeping of the multi-observation backward sweeps.
///
/// This is the **single** recorder implementation; the solo
/// [`GridRecorder`] is a thin `B = 1` wrapper over it.
pub struct BatchGridRecorder {
    /// Per sample: accepted step end times (snapped exactly onto barriers)
    /// plus the starting point `t0`.
    pub times: Vec<Vec<f64>>,
    pub trials_per_step: Vec<Vec<usize>>,
    /// Per sample: `(k, steps_done)` — observation `k` of the grid was hit
    /// after `steps_done` accepted steps (i.e. at `times[sample][steps_done]`).
    pub obs_marks: Vec<Vec<(usize, usize)>>,
}

impl BatchGridRecorder {
    pub fn new(t0: f64, batch: usize) -> Self {
        BatchGridRecorder {
            times: vec![vec![t0]; batch],
            trials_per_step: vec![Vec::new(); batch],
            obs_marks: vec![Vec::new(); batch],
        }
    }
}

impl BatchStepObserver for BatchGridRecorder {
    fn on_accept(&mut self, step: &BatchAcceptedStep) {
        self.times[step.sample].push(step.t_end);
        self.trials_per_step[step.sample].push(step.trials);
    }

    fn on_observation(&mut self, sample: usize, k: usize, _t: f64, _z: &[f32], _v: Option<&[f32]>) {
        let steps_done = self.times[sample].len() - 1;
        self.obs_marks[sample].push((k, steps_done));
    }
}

/// Convenience: integrate and also record the accepted time grid — what
/// MALI keeps from the forward pass (paper Algo. 4 "keep accepted
/// discretized time points").  A thin single-sample wrapper over
/// [`BatchGridRecorder`] so the grid/observation bookkeeping exists once.
pub struct GridRecorder(BatchGridRecorder);

impl GridRecorder {
    pub fn new(t0: f64) -> Self {
        GridRecorder(BatchGridRecorder::new(t0, 1))
    }

    /// Accepted step end times plus the starting point `t0`.
    pub fn times(&self) -> &[f64] {
        &self.0.times[0]
    }

    pub fn trials_per_step(&self) -> &[usize] {
        &self.0.trials_per_step[0]
    }

    /// `(k, steps_done)` observation marks — see
    /// [`BatchGridRecorder::obs_marks`].
    pub fn obs_marks(&self) -> &[(usize, usize)] {
        &self.0.obs_marks[0]
    }
}

impl StepObserver for GridRecorder {
    fn on_accept(&mut self, step: &AcceptedStep) {
        self.0.on_accept(&BatchAcceptedStep {
            sample: 0,
            index: step.index,
            t: step.t,
            h: step.h,
            t_end: step.t_end,
            before_z: &step.before.z,
            before_v: step.before.v.as_deref(),
            after_z: &step.after.z,
            after_v: step.after.v.as_deref(),
            trials: step.trials,
        });
    }

    fn on_observation(&mut self, k: usize, t: f64, state: &State) {
        self.0.on_observation(0, k, t, &state.z, state.v.as_deref());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::by_name;
    use crate::solvers::dynamics::LinearToy;

    fn exp_err(solver: &str, mode: &StepMode) -> f64 {
        let toy = LinearToy::new(1.0, 1);
        let s = by_name(solver).unwrap();
        let s0 = s.init(&toy, 0.0, &[1.0]);
        let (sf, _) = integrate(&*s, &toy, 0.0, 1.0, s0, mode, &ErrorNorm::Full, &mut ())
            .unwrap();
        ((sf.z[0] as f64) - 1f64.exp()).abs()
    }

    #[test]
    fn fixed_step_converges_exp() {
        let coarse = exp_err("rk4", &StepMode::Fixed { h: 0.25 });
        let fine = exp_err("rk4", &StepMode::Fixed { h: 0.05 });
        assert!(coarse < 1e-4);
        assert!(fine < coarse);
    }

    #[test]
    fn alf_global_order_two() {
        // global error should drop ~4x when h halves
        let e1 = exp_err("alf", &StepMode::Fixed { h: 0.1 });
        let e2 = exp_err("alf", &StepMode::Fixed { h: 0.05 });
        let ratio = e1 / e2.max(1e-300);
        assert!(ratio > 2.8, "expected ~4x, got {ratio} ({e1} / {e2})");
    }

    #[test]
    fn adaptive_meets_tolerance() {
        for solver in ["alf", "heun-euler", "rk23", "dopri5"] {
            let err = exp_err(solver, &StepMode::adaptive(1e-6, 1e-8));
            assert!(err < 1e-4, "{solver}: err {err}");
        }
    }

    #[test]
    fn adaptive_tighter_tol_means_more_steps() {
        let toy = LinearToy::new(1.0, 1);
        let s = by_name("dopri5").unwrap();
        let run = |rtol: f64| {
            let s0 = s.init(&toy, 0.0, &[1.0]);
            let (_, st) = integrate(
                &*s,
                &toy,
                0.0,
                5.0,
                s0,
                &StepMode::adaptive(rtol, rtol * 1e-2),
                &ErrorNorm::Full,
                &mut (),
            )
            .unwrap();
            st.n_accepted
        };
        assert!(run(1e-8) > run(1e-3));
    }

    #[test]
    fn reverse_time_integration() {
        // integrate forward then backward with tight tolerance: round trip
        let toy = LinearToy::new(0.8, 1);
        let s = by_name("dopri5").unwrap();
        let s0 = s.init(&toy, 0.0, &[1.0]);
        let mode = StepMode::adaptive(1e-9, 1e-11);
        let (sf, _) =
            integrate(&*s, &toy, 0.0, 2.0, s0, &mode, &ErrorNorm::Full, &mut ()).unwrap();
        let (sb, _) =
            integrate(&*s, &toy, 2.0, 0.0, sf, &mode, &ErrorNorm::Full, &mut ()).unwrap();
        assert!((sb.z[0] - 1.0).abs() < 1e-4, "round trip {}", sb.z[0]);
    }

    #[test]
    fn grid_recorder_lands_exactly_on_endpoint() {
        let toy = LinearToy::new(1.0, 1);
        let s = by_name("alf").unwrap();
        let s0 = s.init(&toy, 0.0, &[1.0]);
        let mut rec = GridRecorder::new(0.0);
        let (_, stats) = integrate(
            &*s,
            &toy,
            0.0,
            1.0,
            s0,
            &StepMode::adaptive(1e-3, 1e-5),
            &ErrorNorm::Full,
            &mut rec,
        )
        .unwrap();
        assert_eq!(rec.times().len(), stats.n_accepted + 1);
        // the final step aims at t1 and lands on it bitwise
        assert_eq!(*rec.times().last().unwrap(), 1.0);
        // strictly increasing grid
        for w in rec.times().windows(2) {
            assert!(w[1] > w[0]);
        }
        // m ≥ 1
        assert!(stats.m() >= 1.0);
    }

    #[test]
    fn obs_grid_validation() {
        assert!(ObsGrid::new(vec![0.5, 0.25, 0.75]).is_err(), "unsorted");
        assert!(ObsGrid::new(vec![0.5, 0.5]).is_err(), "duplicate");
        assert!(ObsGrid::new(vec![f64::NAN]).is_err(), "non-finite");
        let g = ObsGrid::new(vec![0.25, 0.5, 1.0]).unwrap();
        assert!(g.validate_for(0.0, 1.0).is_ok());
        assert!(g.validate_for(0.0, 0.75).is_err(), "obs beyond t1");
        assert!(g.validate_for(0.5, 1.0).is_err(), "obs at/before t0");
        assert!(g.validate_for(1.0, 0.0).is_err(), "wrong direction");
        // reverse-time grids are fine when decreasing
        let r = ObsGrid::new(vec![0.75, 0.25]).unwrap();
        assert!(r.validate_for(1.0, 0.0).is_ok());
        // zero-span with observations is rejected loudly
        let toy = LinearToy::new(1.0, 1);
        let s = by_name("alf").unwrap();
        let s0 = s.init(&toy, 0.0, &[1.0]);
        assert!(integrate_obs(
            &*s,
            &toy,
            0.5,
            0.5,
            s0,
            &StepMode::Fixed { h: 0.1 },
            &ErrorNorm::Full,
            &g,
            &mut (),
        )
        .is_err());
    }

    #[test]
    fn obs_grid_uniform_layout() {
        let g = ObsGrid::uniform(0.0, 1.0, 4);
        assert_eq!(g.times(), &[0.25, 0.5, 0.75, 1.0]);
        assert_eq!(g.time(3), 1.0);
        assert!(ObsGrid::uniform(0.0, 1.0, 0).is_empty());
    }

    /// Adaptive stepping lands bitwise on every observation time, fires
    /// the callbacks in order, and the accepted grid contains the
    /// observation times exactly.
    #[test]
    fn adaptive_obs_exact_hit() {
        struct Seen(Vec<(usize, f64)>);
        impl StepObserver for Seen {
            fn on_observation(&mut self, k: usize, t: f64, _state: &State) {
                self.0.push((k, t));
            }
        }
        let toy = LinearToy::new(0.9, 2);
        let grid = ObsGrid::new(vec![0.31, 0.5, 0.77, 1.3, 2.0]).unwrap();
        for solver in ["alf", "dopri5"] {
            let s = by_name(solver).unwrap();
            let s0 = s.init(&toy, 0.0, &[1.0, -0.5]);
            let mut rec = GridRecorder::new(0.0);
            let mut seen = Seen(Vec::new());
            struct Both<'a>(&'a mut GridRecorder, &'a mut Seen);
            impl StepObserver for Both<'_> {
                fn on_accept(&mut self, step: &AcceptedStep) {
                    self.0.on_accept(step);
                }
                fn on_observation(&mut self, k: usize, t: f64, state: &State) {
                    self.0.on_observation(k, t, state);
                    self.1.on_observation(k, t, state);
                }
            }
            let (_, stats) = integrate_obs(
                &*s,
                &toy,
                0.0,
                2.0,
                s0,
                &StepMode::adaptive(1e-4, 1e-6),
                &ErrorNorm::Full,
                &grid,
                &mut Both(&mut rec, &mut seen),
            )
            .unwrap();
            assert_eq!(seen.0.len(), grid.len(), "{solver}: all observations fired");
            for (k, (got_k, got_t)) in seen.0.iter().enumerate() {
                assert_eq!(*got_k, k, "{solver}: observation order");
                // bitwise landing
                assert_eq!(*got_t, grid.time(k), "{solver}: exact hit at obs {k}");
                assert!(
                    rec.times().contains(got_t),
                    "{solver}: accepted grid contains obs {k}"
                );
            }
            assert_eq!(rec.obs_marks().len(), grid.len());
            for &(k, steps_done) in rec.obs_marks() {
                assert_eq!(rec.times()[steps_done], grid.time(k), "mark placement");
            }
            assert!(stats.n_accepted >= grid.len(), "{solver}");
        }
    }

    /// A grid containing only the endpoint is *indistinguishable* from the
    /// empty grid: the clamp target is t1 either way, so every controller
    /// decision, accepted time, trial count and the final state are
    /// identical — the pin for "empty grid == pre-observation behaviour".
    #[test]
    fn endpoint_only_grid_identical_to_empty() {
        let toy = LinearToy::new(1.1, 3);
        let s = by_name("alf").unwrap();
        let mode = StepMode::adaptive(1e-5, 1e-7);
        let z0 = [1.0f32, 0.3, -2.0];

        let s0 = s.init(&toy, 0.0, &z0);
        let mut rec_a = GridRecorder::new(0.0);
        let (fa, sa) =
            integrate(&*s, &toy, 0.0, 1.7, s0, &mode, &ErrorNorm::Full, &mut rec_a).unwrap();

        let grid = ObsGrid::new(vec![1.7]).unwrap();
        let s0 = s.init(&toy, 0.0, &z0);
        let mut rec_b = GridRecorder::new(0.0);
        let (fb, sb) = integrate_obs(
            &*s,
            &toy,
            0.0,
            1.7,
            s0,
            &mode,
            &ErrorNorm::Full,
            &grid,
            &mut rec_b,
        )
        .unwrap();

        assert_eq!(fa.z, fb.z, "final state bitwise");
        assert_eq!(fa.v, fb.v, "final v bitwise");
        assert_eq!(sa.n_accepted, sb.n_accepted);
        assert_eq!(sa.n_trials, sb.n_trials);
        assert_eq!(sa.f_evals, sb.f_evals);
        assert_eq!(rec_a.times(), rec_b.times(), "accepted grids bitwise");
        // the only difference: the observation fired, exactly at t1
        assert_eq!(rec_a.obs_marks().len(), 0);
        assert_eq!(rec_b.obs_marks(), &[(0, sa.n_accepted)]);
    }

    /// Fixed-mode observation segmentation reproduces exactly the grid a
    /// segment-wise caller (the legacy latent-ODE loop) would have taken:
    /// per segment ⌈|seg|/h⌉ equal steps, landing on every boundary.
    #[test]
    fn fixed_obs_segments_match_segmentwise_calls() {
        let toy = LinearToy::new(-0.4, 2);
        let s = by_name("alf").unwrap();
        let h = 0.25;
        let obs_times = [0.34, 0.5, 1.0];
        let grid = ObsGrid::new(obs_times.to_vec()).unwrap();

        let s0 = s.init(&toy, 0.0, &[1.0, 2.0]);
        let mut rec = GridRecorder::new(0.0);
        let (_, stats) = integrate_obs(
            &*s,
            &toy,
            0.0,
            1.0,
            s0,
            &StepMode::Fixed { h },
            &ErrorNorm::Full,
            &grid,
            &mut rec,
        )
        .unwrap();

        // expected per-segment step counts: ceil(0.34/0.25)=2,
        // ceil(0.16/0.25)=1, ceil(0.5/0.25)=2 — and no trailing segment
        // because the last observation is t1
        assert_eq!(stats.n_accepted, 5);
        for &t in &obs_times {
            assert!(rec.times().contains(&t), "grid lands on {t}");
        }
        assert_eq!(
            rec.obs_marks(),
            &[(0, 2), (1, 3), (2, 5)],
            "observation marks at segment boundaries"
        );
        assert_eq!(*rec.times().last().unwrap(), 1.0);
    }

    /// Batched obs-aware integration: every row of a batch hits every
    /// observation bitwise and matches a solo run of that row
    /// decision-for-decision (grids, marks, trials).
    #[test]
    fn batched_obs_matches_solo_rows() {
        use crate::solvers::batch::BatchSpec;
        let toy = LinearToy::new(0.9, 1);
        let s = by_name("alf").unwrap();
        let mode = StepMode::adaptive(1e-4, 1e-6);
        let grid = ObsGrid::new(vec![0.4, 1.1, 2.0]).unwrap();
        let rows: [f32; 3] = [0.001, 0.7, 4.0];

        let mut solo_grids = Vec::new();
        let mut solo_marks = Vec::new();
        for &z in &rows {
            let s0 = s.init(&toy, 0.0, &[z]);
            let mut rec = GridRecorder::new(0.0);
            integrate_obs(
                &*s,
                &toy,
                0.0,
                2.0,
                s0,
                &mode,
                &ErrorNorm::Full,
                &grid,
                &mut rec,
            )
            .unwrap();
            solo_grids.push(rec.times().to_vec());
            solo_marks.push(rec.obs_marks().to_vec());
        }

        let spec = BatchSpec::new(3, 1);
        let b0 = s.init_batch(&toy, 0.0, &rows, &spec);
        let mut rec = BatchGridRecorder::new(0.0, 3);
        integrate_batch_obs(
            &*s,
            &toy,
            0.0,
            2.0,
            b0,
            &mode,
            &ErrorNorm::Full,
            &grid,
            &mut rec,
        )
        .unwrap();

        for b in 0..3 {
            assert_eq!(rec.times[b], solo_grids[b], "grid row {b} bitwise");
            assert_eq!(rec.obs_marks[b], solo_marks[b], "marks row {b}");
            // every observation time is in the row's accepted grid, bitwise
            for &t in grid.times() {
                assert!(rec.times[b].contains(&t), "row {b} lands on {t}");
            }
        }
    }

    /// Batched integration of B copies of the same IVP at different
    /// initial conditions: every row's trajectory, accepted grid and trial
    /// count must equal a solo run of that row — the active mask must not
    /// change any controller decision.
    #[test]
    fn batched_adaptive_matches_solo_rows() {
        use crate::solvers::batch::{BatchSpec, BatchState};
        let toy = LinearToy::new(0.9, 1);
        let s = by_name("alf").unwrap();
        let mode = StepMode::adaptive(1e-4, 1e-6);
        // rows at very different scales → different per-sample grids (the
        // tiny row is atol-dominated, so its controller takes larger steps)
        let rows: [f32; 4] = [0.001, 0.4, 1.0, 5.0];

        let mut solo_final = Vec::new();
        let mut solo_grids = Vec::new();
        let mut solo_stats = Vec::new();
        for &z in &rows {
            let s0 = s.init(&toy, 0.0, &[z]);
            let mut rec = GridRecorder::new(0.0);
            let (sf, st) =
                integrate(&*s, &toy, 0.0, 2.0, s0, &mode, &ErrorNorm::Full, &mut rec).unwrap();
            solo_final.push(sf.z[0]);
            solo_grids.push(rec.times().to_vec());
            solo_stats.push(st);
        }

        let spec = BatchSpec::new(4, 1);
        let b0 = s.init_batch(&toy, 0.0, &rows, &spec);
        assert_eq!(b0.spec(), spec);
        let mut rec = BatchGridRecorder::new(0.0, 4);
        let (bf, bstats) =
            integrate_batch(&*s, &toy, 0.0, 2.0, b0, &mode, &ErrorNorm::Full, &mut rec)
                .unwrap();

        for b in 0..4 {
            assert_eq!(bf.z.data[b], solo_final[b], "final z row {b}");
            assert_eq!(
                bstats.per_sample[b].n_accepted, solo_stats[b].n_accepted,
                "accepted-step count row {b}"
            );
            assert_eq!(
                bstats.per_sample[b].n_trials, solo_stats[b].n_trials,
                "trial count row {b}"
            );
            assert_eq!(rec.times[b].len(), solo_grids[b].len());
            for (a, bt) in rec.times[b].iter().zip(&solo_grids[b]) {
                assert!((a - bt).abs() < 1e-14, "grid row {b}: {a} vs {bt}");
            }
        }
        // different rows genuinely took different grids
        assert_ne!(
            bstats.per_sample[0].n_accepted,
            bstats.per_sample[3].n_accepted
        );
        // total f-evals equals the sum of the solo runs'
        let solo_f: u64 = solo_stats.iter().map(|s| s.f_evals).sum();
        assert_eq!(bstats.f_evals, solo_f);
        assert_eq!(bstats.aggregate().n_accepted, bstats.n_accepted_total());
    }

    #[test]
    fn batched_fixed_steps_in_lockstep() {
        use crate::solvers::batch::BatchSpec;
        let toy = LinearToy::new(1.0, 2);
        let s = by_name("rk4").unwrap();
        let spec = BatchSpec::new(3, 2);
        let z0: Vec<f32> = vec![1.0, 2.0, 0.5, -0.5, 3.0, 0.1];
        let b0 = s.init_batch(&toy, 0.0, &z0, &spec);
        let (bf, st) = integrate_batch(
            &*s,
            &toy,
            0.0,
            1.0,
            b0,
            &StepMode::Fixed { h: 0.1 },
            &ErrorNorm::Full,
            &mut (),
        )
        .unwrap();
        let e = 1f64.exp();
        for (zf, z0i) in bf.z.data.iter().zip(&z0) {
            assert!(((*zf as f64) - (*z0i as f64) * e).abs() < 1e-4 * (1.0 + z0i.abs() as f64));
        }
        for ps in &st.per_sample {
            assert_eq!(ps.n_accepted, 10);
            assert_eq!(ps.n_trials, 10);
        }
    }

    #[test]
    fn fixed_mode_rejects_nonpositive_h() {
        let toy = LinearToy::new(1.0, 1);
        let s = by_name("euler").unwrap();
        let s0 = s.init(&toy, 0.0, &[1.0]);
        assert!(integrate(
            &*s,
            &toy,
            0.0,
            1.0,
            s0,
            &StepMode::Fixed { h: 0.0 },
            &ErrorNorm::Full,
            &mut ()
        )
        .is_err());
    }

    #[test]
    fn euler_has_no_error_estimate() {
        let toy = LinearToy::new(1.0, 1);
        let s = by_name("euler").unwrap();
        let s0 = s.init(&toy, 0.0, &[1.0]);
        assert!(integrate(
            &*s,
            &toy,
            0.0,
            1.0,
            s0,
            &StepMode::adaptive(1e-3, 1e-5),
            &ErrorNorm::Full,
            &mut ()
        )
        .is_err());
    }
}
