//! The numerical-integration driver (paper Algorithm 1): fixed-step and
//! adaptive-step loops over any [`Solver`], with an observer hook that the
//! four gradient protocols use to record exactly what they each need
//! (nothing for MALI beyond the accepted grid, checkpoints for ACA, the
//! full trial tape for naive).
//!
//! Supports reverse-time integration (`t1 < t0`) — the adjoint method's
//! backward IVP runs through the same loop.

use super::dynamics::Dynamics;
use super::{Solver, State};
use crate::tensor::{error_norm, error_seminorm};
use anyhow::{bail, Result};

/// Step-size policy.
#[derive(Debug, Clone)]
pub enum StepMode {
    /// Fixed step of magnitude `h` (sign is derived from direction).
    Fixed { h: f64 },
    /// Adaptive control: accept when the scaled error norm ≤ 1.
    Adaptive {
        rtol: f64,
        atol: f64,
        h_init: f64,
        h_min: f64,
        h_max: f64,
    },
}

impl StepMode {
    pub fn adaptive(rtol: f64, atol: f64) -> StepMode {
        StepMode::Adaptive {
            rtol,
            atol,
            h_init: 0.25,
            h_min: 1e-6,
            h_max: 10.0,
        }
    }
}

/// Error-norm selection: `Semi` masks components out of the norm (the
/// adjoint-seminorm trick of Kidger et al., used as the SemiNorm baseline).
#[derive(Debug, Clone)]
pub enum ErrorNorm {
    Full,
    Semi(Vec<bool>),
}

impl ErrorNorm {
    fn eval(&self, err: &[f32], z0: &[f32], z1: &[f32], rtol: f64, atol: f64) -> f64 {
        match self {
            ErrorNorm::Full => error_norm(err, z0, z1, rtol, atol),
            ErrorNorm::Semi(mask) => error_seminorm(err, z0, z1, mask, rtol, atol),
        }
    }
}

/// An accepted step, as seen by observers.
pub struct AcceptedStep<'a> {
    pub index: usize,
    /// Step start time and (signed) size; the step ends at `t + h`.
    pub t: f64,
    pub h: f64,
    pub before: &'a State,
    pub after: &'a State,
    /// Inner-loop iterations spent on this step (1 = accepted first try).
    pub trials: usize,
}

/// Observer for the integration loop.  Default impls ignore everything, so
/// plain inference passes `&mut ()`.
pub trait StepObserver {
    fn on_accept(&mut self, _step: &AcceptedStep) {}
    /// Every trial (accepted or rejected) with the state bytes it
    /// materialized — the naive method's tape accounting.
    fn on_trial(&mut self, _t: f64, _h: f64, _state_bytes: usize, _accepted: bool) {}
}

impl StepObserver for () {}

/// Statistics of one integration run.
#[derive(Debug, Clone, Default)]
pub struct IntStats {
    pub n_accepted: usize,
    pub n_trials: usize,
    pub f_evals: u64,
}

impl IntStats {
    /// Average inner iterations per accepted step — the paper's `m`.
    pub fn m(&self) -> f64 {
        if self.n_accepted == 0 {
            0.0
        } else {
            self.n_trials as f64 / self.n_accepted as f64
        }
    }
}

/// Integrate from `t0` to `t1` (either direction) starting from `state0`.
/// Returns the final state and stats; accepted steps stream to `obs`.
pub fn integrate(
    solver: &dyn Solver,
    dynamics: &dyn Dynamics,
    t0: f64,
    t1: f64,
    state0: State,
    mode: &StepMode,
    norm: &ErrorNorm,
    obs: &mut dyn StepObserver,
) -> Result<(State, IntStats)> {
    let span = t1 - t0;
    if span == 0.0 {
        return Ok((state0, IntStats::default()));
    }
    let dir = span.signum();
    let f0 = dynamics.counters().f_evals.get();
    let mut stats = IntStats::default();
    let mut state = state0;
    let mut t = t0;

    match *mode {
        StepMode::Fixed { h } => {
            if h <= 0.0 {
                bail!("fixed step size must be positive, got {h}");
            }
            // land exactly on t1: n equal steps of |h'| ≤ h
            let n = (span.abs() / h).ceil().max(1.0) as usize;
            let hs = span / n as f64;
            for i in 0..n {
                let (next, _err) = solver.step(dynamics, t, hs, &state);
                obs.on_trial(t, hs, next.bytes(), true);
                obs.on_accept(&AcceptedStep {
                    index: i,
                    t,
                    h: hs,
                    before: &state,
                    after: &next,
                    trials: 1,
                });
                state = next;
                t += hs;
                stats.n_accepted += 1;
                stats.n_trials += 1;
            }
        }
        StepMode::Adaptive {
            rtol,
            atol,
            h_init,
            h_min,
            h_max,
        } => {
            if !solver.has_error_estimate() {
                bail!(
                    "solver '{}' has no embedded error estimate; use StepMode::Fixed",
                    solver.name()
                );
            }
            let p = solver.order() as f64;
            let mut h = h_init.abs().min(h_max).max(h_min) * dir;
            let eps = 1e-12 * span.abs().max(1.0);
            while (t1 - t) * dir > eps {
                // clamp to not overshoot the end point
                if (t + h - t1) * dir > 0.0 {
                    h = t1 - t;
                }
                let mut trials = 0usize;
                loop {
                    trials += 1;
                    stats.n_trials += 1;
                    let (next, err) = solver.step(dynamics, t, h, &state);
                    let en = norm.eval(
                        err.as_deref().unwrap_or(&[]),
                        &state.z,
                        &next.z,
                        rtol,
                        atol,
                    );
                    obs.on_trial(t, h, next.bytes(), en <= 1.0);
                    let at_floor = h.abs() <= h_min * 1.0000001;
                    if en <= 1.0 || at_floor {
                        // accept
                        obs.on_accept(&AcceptedStep {
                            index: stats.n_accepted,
                            t,
                            h,
                            before: &state,
                            after: &next,
                            trials,
                        });
                        state = next;
                        t += h;
                        stats.n_accepted += 1;
                        // grow for the next step (Hairer's controller)
                        let factor = if en > 0.0 {
                            (0.9 * en.powf(-1.0 / p)).clamp(0.2, 10.0)
                        } else {
                            10.0
                        };
                        h = (h.abs() * factor).clamp(h_min, h_max) * dir;
                        break;
                    }
                    // reject: shrink (paper's DecayFactor with the standard
                    // error-proportional rule)
                    let factor = (0.9 * en.powf(-1.0 / p)).clamp(0.2, 0.9);
                    h = (h.abs() * factor).max(h_min) * dir;
                    if trials > 60 {
                        bail!(
                            "step-size search did not converge at t={t} (h={h}, err={en})"
                        );
                    }
                }
            }
        }
    }
    stats.f_evals = dynamics.counters().f_evals.get() - f0;
    Ok((state, stats))
}

/// Convenience: integrate and also record the accepted time grid — what
/// MALI keeps from the forward pass (paper Algo. 4 "keep accepted
/// discretized time points").
pub struct GridRecorder {
    /// Accepted step start times plus the final endpoint.
    pub times: Vec<f64>,
    pub trials_per_step: Vec<usize>,
}

impl GridRecorder {
    pub fn new(t0: f64) -> Self {
        GridRecorder {
            times: vec![t0],
            trials_per_step: Vec::new(),
        }
    }
}

impl StepObserver for GridRecorder {
    fn on_accept(&mut self, step: &AcceptedStep) {
        self.times.push(step.t + step.h);
        self.trials_per_step.push(step.trials);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::by_name;
    use crate::solvers::dynamics::LinearToy;

    fn exp_err(solver: &str, mode: &StepMode) -> f64 {
        let toy = LinearToy::new(1.0, 1);
        let s = by_name(solver).unwrap();
        let s0 = s.init(&toy, 0.0, &[1.0]);
        let (sf, _) = integrate(&*s, &toy, 0.0, 1.0, s0, mode, &ErrorNorm::Full, &mut ())
            .unwrap();
        ((sf.z[0] as f64) - 1f64.exp()).abs()
    }

    #[test]
    fn fixed_step_converges_exp() {
        let coarse = exp_err("rk4", &StepMode::Fixed { h: 0.25 });
        let fine = exp_err("rk4", &StepMode::Fixed { h: 0.05 });
        assert!(coarse < 1e-4);
        assert!(fine < coarse);
    }

    #[test]
    fn alf_global_order_two() {
        // global error should drop ~4x when h halves
        let e1 = exp_err("alf", &StepMode::Fixed { h: 0.1 });
        let e2 = exp_err("alf", &StepMode::Fixed { h: 0.05 });
        let ratio = e1 / e2.max(1e-300);
        assert!(ratio > 2.8, "expected ~4x, got {ratio} ({e1} / {e2})");
    }

    #[test]
    fn adaptive_meets_tolerance() {
        for solver in ["alf", "heun-euler", "rk23", "dopri5"] {
            let err = exp_err(solver, &StepMode::adaptive(1e-6, 1e-8));
            assert!(err < 1e-4, "{solver}: err {err}");
        }
    }

    #[test]
    fn adaptive_tighter_tol_means_more_steps() {
        let toy = LinearToy::new(1.0, 1);
        let s = by_name("dopri5").unwrap();
        let run = |rtol: f64| {
            let s0 = s.init(&toy, 0.0, &[1.0]);
            let (_, st) = integrate(
                &*s,
                &toy,
                0.0,
                5.0,
                s0,
                &StepMode::adaptive(rtol, rtol * 1e-2),
                &ErrorNorm::Full,
                &mut (),
            )
            .unwrap();
            st.n_accepted
        };
        assert!(run(1e-8) > run(1e-3));
    }

    #[test]
    fn reverse_time_integration() {
        // integrate forward then backward with tight tolerance: round trip
        let toy = LinearToy::new(0.8, 1);
        let s = by_name("dopri5").unwrap();
        let s0 = s.init(&toy, 0.0, &[1.0]);
        let mode = StepMode::adaptive(1e-9, 1e-11);
        let (sf, _) =
            integrate(&*s, &toy, 0.0, 2.0, s0, &mode, &ErrorNorm::Full, &mut ()).unwrap();
        let (sb, _) =
            integrate(&*s, &toy, 2.0, 0.0, sf, &mode, &ErrorNorm::Full, &mut ()).unwrap();
        assert!((sb.z[0] - 1.0).abs() < 1e-4, "round trip {}", sb.z[0]);
    }

    #[test]
    fn grid_recorder_lands_exactly_on_endpoint() {
        let toy = LinearToy::new(1.0, 1);
        let s = by_name("alf").unwrap();
        let s0 = s.init(&toy, 0.0, &[1.0]);
        let mut rec = GridRecorder::new(0.0);
        let (_, stats) = integrate(
            &*s,
            &toy,
            0.0,
            1.0,
            s0,
            &StepMode::adaptive(1e-3, 1e-5),
            &ErrorNorm::Full,
            &mut rec,
        )
        .unwrap();
        assert_eq!(rec.times.len(), stats.n_accepted + 1);
        assert!((rec.times.last().unwrap() - 1.0).abs() < 1e-12);
        // strictly increasing grid
        for w in rec.times.windows(2) {
            assert!(w[1] > w[0]);
        }
        // m ≥ 1
        assert!(stats.m() >= 1.0);
    }

    #[test]
    fn fixed_mode_rejects_nonpositive_h() {
        let toy = LinearToy::new(1.0, 1);
        let s = by_name("euler").unwrap();
        let s0 = s.init(&toy, 0.0, &[1.0]);
        assert!(integrate(
            &*s,
            &toy,
            0.0,
            1.0,
            s0,
            &StepMode::Fixed { h: 0.0 },
            &ErrorNorm::Full,
            &mut ()
        )
        .is_err());
    }

    #[test]
    fn euler_has_no_error_estimate() {
        let toy = LinearToy::new(1.0, 1);
        let s = by_name("euler").unwrap();
        let s0 = s.init(&toy, 0.0, &[1.0]);
        assert!(integrate(
            &*s,
            &toy,
            0.0,
            1.0,
            s0,
            &StepMode::adaptive(1e-3, 1e-5),
            &ErrorNorm::Full,
            &mut ()
        )
        .is_err());
    }
}
