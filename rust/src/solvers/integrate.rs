//! The numerical-integration driver (paper Algorithm 1): fixed-step and
//! adaptive-step loops over any [`Solver`], with an observer hook that the
//! four gradient protocols use to record exactly what they each need
//! (nothing for MALI beyond the accepted grid, checkpoints for ACA, the
//! full trial tape for naive).
//!
//! Supports reverse-time integration (`t1 < t0`) — the adjoint method's
//! backward IVP runs through the same loop.

use super::batch::BatchState;
use super::dynamics::Dynamics;
use super::{Solver, State};
use crate::tensor::{error_norm, error_seminorm};
use anyhow::{bail, Result};

/// Step-size policy.
#[derive(Debug, Clone)]
pub enum StepMode {
    /// Fixed step of magnitude `h` (sign is derived from direction).
    Fixed { h: f64 },
    /// Adaptive control: accept when the scaled error norm ≤ 1.
    Adaptive {
        rtol: f64,
        atol: f64,
        h_init: f64,
        h_min: f64,
        h_max: f64,
    },
}

impl StepMode {
    pub fn adaptive(rtol: f64, atol: f64) -> StepMode {
        StepMode::Adaptive {
            rtol,
            atol,
            h_init: 0.25,
            h_min: 1e-6,
            h_max: 10.0,
        }
    }
}

/// Error-norm selection: `Semi` masks components out of the norm (the
/// adjoint-seminorm trick of Kidger et al., used as the SemiNorm baseline).
#[derive(Debug, Clone)]
pub enum ErrorNorm {
    Full,
    Semi(Vec<bool>),
}

impl ErrorNorm {
    fn eval(&self, err: &[f32], z0: &[f32], z1: &[f32], rtol: f64, atol: f64) -> f64 {
        match self {
            ErrorNorm::Full => error_norm(err, z0, z1, rtol, atol),
            ErrorNorm::Semi(mask) => error_seminorm(err, z0, z1, mask, rtol, atol),
        }
    }
}

/// An accepted step, as seen by observers.
pub struct AcceptedStep<'a> {
    pub index: usize,
    /// Step start time and (signed) size; the step ends at `t + h`.
    pub t: f64,
    pub h: f64,
    pub before: &'a State,
    pub after: &'a State,
    /// Inner-loop iterations spent on this step (1 = accepted first try).
    pub trials: usize,
}

/// Observer for the integration loop.  Default impls ignore everything, so
/// plain inference passes `&mut ()`.
pub trait StepObserver {
    fn on_accept(&mut self, _step: &AcceptedStep) {}
    /// Every trial (accepted or rejected) with the state bytes it
    /// materialized — the naive method's tape accounting.
    fn on_trial(&mut self, _t: f64, _h: f64, _state_bytes: usize, _accepted: bool) {}
}

impl StepObserver for () {}

/// Statistics of one integration run.
#[derive(Debug, Clone, Default)]
pub struct IntStats {
    pub n_accepted: usize,
    pub n_trials: usize,
    pub f_evals: u64,
}

impl IntStats {
    /// Average inner iterations per accepted step — the paper's `m`.
    pub fn m(&self) -> f64 {
        if self.n_accepted == 0 {
            0.0
        } else {
            self.n_trials as f64 / self.n_accepted as f64
        }
    }
}

/// Integrate from `t0` to `t1` (either direction) starting from `state0`.
/// Returns the final state and stats; accepted steps stream to `obs`.
#[allow(clippy::too_many_arguments)]
pub fn integrate(
    solver: &dyn Solver,
    dynamics: &dyn Dynamics,
    t0: f64,
    t1: f64,
    state0: State,
    mode: &StepMode,
    norm: &ErrorNorm,
    obs: &mut dyn StepObserver,
) -> Result<(State, IntStats)> {
    let span = t1 - t0;
    if span == 0.0 {
        return Ok((state0, IntStats::default()));
    }
    let dir = span.signum();
    let f0 = dynamics.counters().f_evals.get();
    let mut stats = IntStats::default();
    let mut state = state0;
    let mut t = t0;

    match *mode {
        StepMode::Fixed { h } => {
            if h <= 0.0 {
                bail!("fixed step size must be positive, got {h}");
            }
            // land exactly on t1: n equal steps of |h'| ≤ h
            let n = (span.abs() / h).ceil().max(1.0) as usize;
            let hs = span / n as f64;
            for i in 0..n {
                let (next, _err) = solver.step(dynamics, t, hs, &state);
                obs.on_trial(t, hs, next.bytes(), true);
                obs.on_accept(&AcceptedStep {
                    index: i,
                    t,
                    h: hs,
                    before: &state,
                    after: &next,
                    trials: 1,
                });
                state = next;
                t += hs;
                stats.n_accepted += 1;
                stats.n_trials += 1;
            }
        }
        StepMode::Adaptive {
            rtol,
            atol,
            h_init,
            h_min,
            h_max,
        } => {
            if !solver.has_error_estimate() {
                bail!(
                    "solver '{}' has no embedded error estimate; use StepMode::Fixed",
                    solver.name()
                );
            }
            let p = solver.order() as f64;
            let mut h = h_init.abs().min(h_max).max(h_min) * dir;
            let eps = 1e-12 * span.abs().max(1.0);
            while (t1 - t) * dir > eps {
                // clamp to not overshoot the end point
                if (t + h - t1) * dir > 0.0 {
                    h = t1 - t;
                }
                let mut trials = 0usize;
                loop {
                    trials += 1;
                    stats.n_trials += 1;
                    let (next, err) = solver.step(dynamics, t, h, &state);
                    let en = norm.eval(
                        err.as_deref().unwrap_or(&[]),
                        &state.z,
                        &next.z,
                        rtol,
                        atol,
                    );
                    obs.on_trial(t, h, next.bytes(), en <= 1.0);
                    let at_floor = h.abs() <= h_min * 1.0000001;
                    if en <= 1.0 || at_floor {
                        // accept
                        obs.on_accept(&AcceptedStep {
                            index: stats.n_accepted,
                            t,
                            h,
                            before: &state,
                            after: &next,
                            trials,
                        });
                        state = next;
                        t += h;
                        stats.n_accepted += 1;
                        // grow for the next step (Hairer's controller)
                        let factor = if en > 0.0 {
                            (0.9 * en.powf(-1.0 / p)).clamp(0.2, 10.0)
                        } else {
                            10.0
                        };
                        h = (h.abs() * factor).clamp(h_min, h_max) * dir;
                        break;
                    }
                    // reject: shrink (paper's DecayFactor with the standard
                    // error-proportional rule)
                    let factor = (0.9 * en.powf(-1.0 / p)).clamp(0.2, 0.9);
                    h = (h.abs() * factor).max(h_min) * dir;
                    if trials > 60 {
                        bail!(
                            "step-size search did not converge at t={t} (h={h}, err={en})"
                        );
                    }
                }
            }
        }
    }
    stats.f_evals = dynamics.counters().f_evals.get() - f0;
    Ok((state, stats))
}

// ---------------------------------------------------------------------------
// Batch-first integration: per-sample step control with an active mask.
// ---------------------------------------------------------------------------

/// One accepted step of one sample inside a batched integration, seen by
/// [`BatchStepObserver`]s.  Rows are borrowed from the batch buffers —
/// observers copy only what they retain (checkpoints, tapes).
pub struct BatchAcceptedStep<'a> {
    /// Which sample (batch row) this step belongs to.
    pub sample: usize,
    /// Per-sample accepted-step index.
    pub index: usize,
    /// Step start time and (signed) size; the step ends at `t + h`.
    pub t: f64,
    pub h: f64,
    pub before_z: &'a [f32],
    pub before_v: Option<&'a [f32]>,
    pub after_z: &'a [f32],
    pub after_v: Option<&'a [f32]>,
    /// Inner-loop iterations this sample spent on this step.
    pub trials: usize,
}

impl BatchAcceptedStep<'_> {
    /// The step's input state as an owned single-sample [`State`].
    pub fn before_state(&self) -> State {
        State {
            z: self.before_z.to_vec(),
            v: self.before_v.map(|v| v.to_vec()),
        }
    }
}

/// Observer for [`integrate_batch`]; like [`StepObserver`] but per sample.
pub trait BatchStepObserver {
    fn on_accept(&mut self, _step: &BatchAcceptedStep) {}
    /// Every trial of one sample (accepted or rejected) with the row bytes
    /// it materialized.
    fn on_trial(&mut self, _sample: usize, _t: f64, _h: f64, _state_bytes: usize, _accepted: bool) {
    }
}

impl BatchStepObserver for () {}

/// Statistics of one batched integration run.
///
/// `per_sample[b]` carries the *structural* counts (accepted steps,
/// trials) of sample `b` — exactly what a solo run of that row would
/// report; `f_evals` is the total across the batch (per-sample `f`
/// attribution is not tracked, so `per_sample[b].f_evals` is 0).
#[derive(Debug, Clone, Default)]
pub struct BatchIntStats {
    pub per_sample: Vec<IntStats>,
    /// Total `f` evaluations across the batch (counter delta).
    pub f_evals: u64,
}

impl BatchIntStats {
    /// Total accepted steps across the batch.
    pub fn n_accepted_total(&self) -> usize {
        self.per_sample.iter().map(|s| s.n_accepted).sum()
    }

    /// Total trials across the batch.
    pub fn n_trials_total(&self) -> usize {
        self.per_sample.iter().map(|s| s.n_trials).sum()
    }

    /// Largest per-sample accepted-step count (the longest chain any
    /// gradient flows through).
    pub fn n_accepted_max(&self) -> usize {
        self.per_sample.iter().map(|s| s.n_accepted).max().unwrap_or(0)
    }

    /// Batch-aggregated [`IntStats`] (sums; `m()` becomes the batch mean).
    pub fn aggregate(&self) -> IntStats {
        IntStats {
            n_accepted: self.n_accepted_total(),
            n_trials: self.n_trials_total(),
            f_evals: self.f_evals,
        }
    }
}

/// Integrate a batch of independent trajectories from `t0` to `t1`.
///
/// * `Fixed` mode steps all rows in lockstep on the shared grid — one
///   batched solver step (and thus one batched `f` per stage) per grid
///   point.
/// * `Adaptive` mode gives every sample its own step-size controller
///   (identical, decision-for-decision, to a solo [`integrate`] run of
///   that row) and keeps an **active mask**: rows that reached `t1` are
///   dropped from the gathered sub-batch, so early-converged samples stop
///   consuming `f` evaluations while stragglers finish.
///
/// A `Semi` error norm is applied per row and its mask must have length
/// `n_z` (one row width).
#[allow(clippy::too_many_arguments)]
pub fn integrate_batch(
    solver: &dyn Solver,
    dynamics: &dyn Dynamics,
    t0: f64,
    t1: f64,
    state0: BatchState,
    mode: &StepMode,
    norm: &ErrorNorm,
    obs: &mut dyn BatchStepObserver,
) -> Result<(BatchState, BatchIntStats)> {
    let spec = state0.spec();
    let nb = spec.batch;
    let span = t1 - t0;
    let f0 = dynamics.counters().f_evals.get();
    let mut per = vec![IntStats::default(); nb];
    if span == 0.0 {
        return Ok((
            state0,
            BatchIntStats {
                per_sample: per,
                f_evals: 0,
            },
        ));
    }
    let dir = span.signum();
    let mut state = state0;

    match *mode {
        StepMode::Fixed { h } => {
            if h <= 0.0 {
                bail!("fixed step size must be positive, got {h}");
            }
            let n = (span.abs() / h).ceil().max(1.0) as usize;
            let hs = span / n as f64;
            let hs_row = vec![hs; nb];
            let mut ts_buf = vec![t0; nb];
            let mut t = t0;
            for i in 0..n {
                ts_buf.fill(t);
                let (next, _err) = solver.step_batch(dynamics, &ts_buf, &hs_row, &state);
                let row_bytes = next.row_bytes();
                for (b, st) in per.iter_mut().enumerate() {
                    obs.on_trial(b, t, hs, row_bytes, true);
                    obs.on_accept(&BatchAcceptedStep {
                        sample: b,
                        index: i,
                        t,
                        h: hs,
                        before_z: spec.row(&state.z.data, b),
                        before_v: state.v.as_ref().map(|v| spec.row(&v.data, b)),
                        after_z: spec.row(&next.z.data, b),
                        after_v: next.v.as_ref().map(|v| spec.row(&v.data, b)),
                        trials: 1,
                    });
                    st.n_accepted += 1;
                    st.n_trials += 1;
                }
                state = next;
                t += hs;
            }
        }
        StepMode::Adaptive {
            rtol,
            atol,
            h_init,
            h_min,
            h_max,
        } => {
            if !solver.has_error_estimate() {
                bail!(
                    "solver '{}' has no embedded error estimate; use StepMode::Fixed",
                    solver.name()
                );
            }
            if let ErrorNorm::Semi(m) = norm {
                if m.len() != spec.n_z {
                    bail!(
                        "batched seminorm mask has length {}, want one row width {}",
                        m.len(),
                        spec.n_z
                    );
                }
            }
            let p = solver.order() as f64;
            let eps = 1e-12 * span.abs().max(1.0);
            let h0 = h_init.abs().min(h_max).max(h_min) * dir;
            // per-sample controller state — decision-identical to solo runs
            let mut t_cur = vec![t0; nb];
            let mut h_cur = vec![h0; nb];
            let mut trials_cur = vec![0usize; nb];
            let mut accepted_idx = vec![0usize; nb];
            // same entry condition as the solo loop: a sub-eps span means
            // zero steps
            let mut active: Vec<usize> = if span.abs() > eps {
                (0..nb).collect()
            } else {
                Vec::new()
            };
            while !active.is_empty() {
                // start-of-step overshoot clamp for rows opening a new step
                for &b in &active {
                    if trials_cur[b] == 0 && (t_cur[b] + h_cur[b] - t1) * dir > 0.0 {
                        h_cur[b] = t1 - t_cur[b];
                    }
                }
                let ts: Vec<f64> = active.iter().map(|&b| t_cur[b]).collect();
                let hs: Vec<f64> = active.iter().map(|&b| h_cur[b]).collect();
                // skip the row gather while every sample is still active
                let (next_sub, err_sub) = if active.len() == nb {
                    solver.step_batch(dynamics, &ts, &hs, &state)
                } else {
                    let sub = state.gather_rows(&active);
                    solver.step_batch(dynamics, &ts, &hs, &sub)
                };
                let sub_spec = next_sub.spec();
                let row_bytes = next_sub.row_bytes();
                let mut still = Vec::with_capacity(active.len());
                for (k, &b) in active.iter().enumerate() {
                    trials_cur[b] += 1;
                    per[b].n_trials += 1;
                    let err_row: &[f32] = match &err_sub {
                        Some(e) => sub_spec.row(e, k),
                        None => &[],
                    };
                    let en = norm.eval(
                        err_row,
                        spec.row(&state.z.data, b),
                        sub_spec.row(&next_sub.z.data, k),
                        rtol,
                        atol,
                    );
                    obs.on_trial(b, t_cur[b], h_cur[b], row_bytes, en <= 1.0);
                    let at_floor = h_cur[b].abs() <= h_min * 1.0000001;
                    if en <= 1.0 || at_floor {
                        // accept this sample's step
                        obs.on_accept(&BatchAcceptedStep {
                            sample: b,
                            index: accepted_idx[b],
                            t: t_cur[b],
                            h: h_cur[b],
                            before_z: spec.row(&state.z.data, b),
                            before_v: state.v.as_ref().map(|v| spec.row(&v.data, b)),
                            after_z: sub_spec.row(&next_sub.z.data, k),
                            after_v: next_sub.v.as_ref().map(|v| sub_spec.row(&v.data, k)),
                            trials: trials_cur[b],
                        });
                        state.copy_row_from(b, &next_sub, k);
                        t_cur[b] += h_cur[b];
                        per[b].n_accepted += 1;
                        accepted_idx[b] += 1;
                        // grow for the next step (Hairer's controller)
                        let factor = if en > 0.0 {
                            (0.9 * en.powf(-1.0 / p)).clamp(0.2, 10.0)
                        } else {
                            10.0
                        };
                        h_cur[b] = (h_cur[b].abs() * factor).clamp(h_min, h_max) * dir;
                        trials_cur[b] = 0;
                        if (t1 - t_cur[b]) * dir > eps {
                            still.push(b); // not there yet — stays active
                        }
                    } else {
                        // reject: shrink (same error-proportional rule as solo)
                        let factor = (0.9 * en.powf(-1.0 / p)).clamp(0.2, 0.9);
                        h_cur[b] = (h_cur[b].abs() * factor).max(h_min) * dir;
                        if trials_cur[b] > 60 {
                            bail!(
                                "step-size search did not converge for sample {b} at t={} (h={}, err={en})",
                                t_cur[b],
                                h_cur[b]
                            );
                        }
                        still.push(b);
                    }
                }
                active = still;
            }
        }
    }
    let stats = BatchIntStats {
        per_sample: per,
        f_evals: dynamics.counters().f_evals.get() - f0,
    };
    Ok((state, stats))
}

/// Per-sample accepted-grid recorder — what batched MALI keeps from the
/// forward pass (paper Algo. 4, one grid per sample).
pub struct BatchGridRecorder {
    /// Per sample: accepted step start times plus the final endpoint.
    pub times: Vec<Vec<f64>>,
    pub trials_per_step: Vec<Vec<usize>>,
}

impl BatchGridRecorder {
    pub fn new(t0: f64, batch: usize) -> Self {
        BatchGridRecorder {
            times: vec![vec![t0]; batch],
            trials_per_step: vec![Vec::new(); batch],
        }
    }
}

impl BatchStepObserver for BatchGridRecorder {
    fn on_accept(&mut self, step: &BatchAcceptedStep) {
        self.times[step.sample].push(step.t + step.h);
        self.trials_per_step[step.sample].push(step.trials);
    }
}

/// Convenience: integrate and also record the accepted time grid — what
/// MALI keeps from the forward pass (paper Algo. 4 "keep accepted
/// discretized time points").
pub struct GridRecorder {
    /// Accepted step start times plus the final endpoint.
    pub times: Vec<f64>,
    pub trials_per_step: Vec<usize>,
}

impl GridRecorder {
    pub fn new(t0: f64) -> Self {
        GridRecorder {
            times: vec![t0],
            trials_per_step: Vec::new(),
        }
    }
}

impl StepObserver for GridRecorder {
    fn on_accept(&mut self, step: &AcceptedStep) {
        self.times.push(step.t + step.h);
        self.trials_per_step.push(step.trials);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::by_name;
    use crate::solvers::dynamics::LinearToy;

    fn exp_err(solver: &str, mode: &StepMode) -> f64 {
        let toy = LinearToy::new(1.0, 1);
        let s = by_name(solver).unwrap();
        let s0 = s.init(&toy, 0.0, &[1.0]);
        let (sf, _) = integrate(&*s, &toy, 0.0, 1.0, s0, mode, &ErrorNorm::Full, &mut ())
            .unwrap();
        ((sf.z[0] as f64) - 1f64.exp()).abs()
    }

    #[test]
    fn fixed_step_converges_exp() {
        let coarse = exp_err("rk4", &StepMode::Fixed { h: 0.25 });
        let fine = exp_err("rk4", &StepMode::Fixed { h: 0.05 });
        assert!(coarse < 1e-4);
        assert!(fine < coarse);
    }

    #[test]
    fn alf_global_order_two() {
        // global error should drop ~4x when h halves
        let e1 = exp_err("alf", &StepMode::Fixed { h: 0.1 });
        let e2 = exp_err("alf", &StepMode::Fixed { h: 0.05 });
        let ratio = e1 / e2.max(1e-300);
        assert!(ratio > 2.8, "expected ~4x, got {ratio} ({e1} / {e2})");
    }

    #[test]
    fn adaptive_meets_tolerance() {
        for solver in ["alf", "heun-euler", "rk23", "dopri5"] {
            let err = exp_err(solver, &StepMode::adaptive(1e-6, 1e-8));
            assert!(err < 1e-4, "{solver}: err {err}");
        }
    }

    #[test]
    fn adaptive_tighter_tol_means_more_steps() {
        let toy = LinearToy::new(1.0, 1);
        let s = by_name("dopri5").unwrap();
        let run = |rtol: f64| {
            let s0 = s.init(&toy, 0.0, &[1.0]);
            let (_, st) = integrate(
                &*s,
                &toy,
                0.0,
                5.0,
                s0,
                &StepMode::adaptive(rtol, rtol * 1e-2),
                &ErrorNorm::Full,
                &mut (),
            )
            .unwrap();
            st.n_accepted
        };
        assert!(run(1e-8) > run(1e-3));
    }

    #[test]
    fn reverse_time_integration() {
        // integrate forward then backward with tight tolerance: round trip
        let toy = LinearToy::new(0.8, 1);
        let s = by_name("dopri5").unwrap();
        let s0 = s.init(&toy, 0.0, &[1.0]);
        let mode = StepMode::adaptive(1e-9, 1e-11);
        let (sf, _) =
            integrate(&*s, &toy, 0.0, 2.0, s0, &mode, &ErrorNorm::Full, &mut ()).unwrap();
        let (sb, _) =
            integrate(&*s, &toy, 2.0, 0.0, sf, &mode, &ErrorNorm::Full, &mut ()).unwrap();
        assert!((sb.z[0] - 1.0).abs() < 1e-4, "round trip {}", sb.z[0]);
    }

    #[test]
    fn grid_recorder_lands_exactly_on_endpoint() {
        let toy = LinearToy::new(1.0, 1);
        let s = by_name("alf").unwrap();
        let s0 = s.init(&toy, 0.0, &[1.0]);
        let mut rec = GridRecorder::new(0.0);
        let (_, stats) = integrate(
            &*s,
            &toy,
            0.0,
            1.0,
            s0,
            &StepMode::adaptive(1e-3, 1e-5),
            &ErrorNorm::Full,
            &mut rec,
        )
        .unwrap();
        assert_eq!(rec.times.len(), stats.n_accepted + 1);
        assert!((rec.times.last().unwrap() - 1.0).abs() < 1e-12);
        // strictly increasing grid
        for w in rec.times.windows(2) {
            assert!(w[1] > w[0]);
        }
        // m ≥ 1
        assert!(stats.m() >= 1.0);
    }

    /// Batched integration of B copies of the same IVP at different
    /// initial conditions: every row's trajectory, accepted grid and trial
    /// count must equal a solo run of that row — the active mask must not
    /// change any controller decision.
    #[test]
    fn batched_adaptive_matches_solo_rows() {
        use crate::solvers::batch::{BatchSpec, BatchState};
        let toy = LinearToy::new(0.9, 1);
        let s = by_name("alf").unwrap();
        let mode = StepMode::adaptive(1e-4, 1e-6);
        // rows at very different scales → different per-sample grids (the
        // tiny row is atol-dominated, so its controller takes larger steps)
        let rows: [f32; 4] = [0.001, 0.4, 1.0, 5.0];

        let mut solo_final = Vec::new();
        let mut solo_grids = Vec::new();
        let mut solo_stats = Vec::new();
        for &z in &rows {
            let s0 = s.init(&toy, 0.0, &[z]);
            let mut rec = GridRecorder::new(0.0);
            let (sf, st) =
                integrate(&*s, &toy, 0.0, 2.0, s0, &mode, &ErrorNorm::Full, &mut rec).unwrap();
            solo_final.push(sf.z[0]);
            solo_grids.push(rec.times);
            solo_stats.push(st);
        }

        let spec = BatchSpec::new(4, 1);
        let b0 = s.init_batch(&toy, 0.0, &rows, &spec);
        assert_eq!(b0.spec(), spec);
        let mut rec = BatchGridRecorder::new(0.0, 4);
        let (bf, bstats) =
            integrate_batch(&*s, &toy, 0.0, 2.0, b0, &mode, &ErrorNorm::Full, &mut rec)
                .unwrap();

        for b in 0..4 {
            assert_eq!(bf.z.data[b], solo_final[b], "final z row {b}");
            assert_eq!(
                bstats.per_sample[b].n_accepted, solo_stats[b].n_accepted,
                "accepted-step count row {b}"
            );
            assert_eq!(
                bstats.per_sample[b].n_trials, solo_stats[b].n_trials,
                "trial count row {b}"
            );
            assert_eq!(rec.times[b].len(), solo_grids[b].len());
            for (a, bt) in rec.times[b].iter().zip(&solo_grids[b]) {
                assert!((a - bt).abs() < 1e-14, "grid row {b}: {a} vs {bt}");
            }
        }
        // different rows genuinely took different grids
        assert_ne!(
            bstats.per_sample[0].n_accepted,
            bstats.per_sample[3].n_accepted
        );
        // total f-evals equals the sum of the solo runs'
        let solo_f: u64 = solo_stats.iter().map(|s| s.f_evals).sum();
        assert_eq!(bstats.f_evals, solo_f);
        assert_eq!(bstats.aggregate().n_accepted, bstats.n_accepted_total());
    }

    #[test]
    fn batched_fixed_steps_in_lockstep() {
        use crate::solvers::batch::BatchSpec;
        let toy = LinearToy::new(1.0, 2);
        let s = by_name("rk4").unwrap();
        let spec = BatchSpec::new(3, 2);
        let z0: Vec<f32> = vec![1.0, 2.0, 0.5, -0.5, 3.0, 0.1];
        let b0 = s.init_batch(&toy, 0.0, &z0, &spec);
        let (bf, st) = integrate_batch(
            &*s,
            &toy,
            0.0,
            1.0,
            b0,
            &StepMode::Fixed { h: 0.1 },
            &ErrorNorm::Full,
            &mut (),
        )
        .unwrap();
        let e = 1f64.exp();
        for (zf, z0i) in bf.z.data.iter().zip(&z0) {
            assert!(((*zf as f64) - (*z0i as f64) * e).abs() < 1e-4 * (1.0 + z0i.abs() as f64));
        }
        for ps in &st.per_sample {
            assert_eq!(ps.n_accepted, 10);
            assert_eq!(ps.n_trials, 10);
        }
    }

    #[test]
    fn fixed_mode_rejects_nonpositive_h() {
        let toy = LinearToy::new(1.0, 1);
        let s = by_name("euler").unwrap();
        let s0 = s.init(&toy, 0.0, &[1.0]);
        assert!(integrate(
            &*s,
            &toy,
            0.0,
            1.0,
            s0,
            &StepMode::Fixed { h: 0.0 },
            &ErrorNorm::Full,
            &mut ()
        )
        .is_err());
    }

    #[test]
    fn euler_has_no_error_estimate() {
        let toy = LinearToy::new(1.0, 1);
        let s = by_name("euler").unwrap();
        let s0 = s.init(&toy, 0.0, &[1.0]);
        assert!(integrate(
            &*s,
            &toy,
            0.0,
            1.0,
            s0,
            &StepMode::adaptive(1e-3, 1e-5),
            &ErrorNorm::Full,
            &mut ()
        )
        .is_err());
    }
}
