//! Reversible-4: a 4th-order algebraically invertible solver built as a
//! Yoshida/Suzuki triple-jump composition of ALF steps.
//!
//! One composed step over `h` applies three ALF sub-steps with sizes
//! `γ₁h, γ₂h, γ₃h` where
//!
//! ```text
//! γ₁ = γ₃ = 1 / (2 − 2^{1/3})          ≈  1.3512
//! γ₂     = −2^{1/3} / (2 − 2^{1/3})    ≈ −1.7024,      γ₁ + γ₂ + γ₃ = 1
//! ```
//!
//! — the classical coefficients that cancel the `h³` term of a
//! time-symmetric second-order base map (Yoshida 1990; Hairer–Lubich–
//! Wanner III.4).  ALF at η = 1 is exactly time-symmetric
//! (`ψ₋ₕ∘ψₕ = id`), so the composition is globally 4th order on
//! consistent data `v₀ = f(z₀, t₀)`; damped η < 1 breaks the symmetry
//! and degrades the order (the factory still honors η for the damped
//! stability experiments — see `docs/adr/008-method-grid.md`).
//!
//! Because each sub-step is an ALF step, the composed map inherits ALF's
//! **exact algebraic inverse**: `Ψ⁻¹ = ψ⁻¹_{γ₁h} ∘ ψ⁻¹_{γ₂h} ∘ ψ⁻¹_{γ₃h}`
//! (the sub-inverses applied in reverse order), so MALI-style
//! constant-memory reverse sweeps, ψ-vjp backward chains, and the serve
//! layer all work unchanged — this solver exists to prove the
//! `Solver`/`GradMethod` surface generalizes beyond the single ALF
//! implementor.  The middle sub-step has `γ₂ < 0` (a backward-in-time
//! ALF step), which is fine algebraically: ψ and ψ⁻¹ are defined for
//! either sign of `h`.
//!
//! Error estimate: the absolute values of the three embedded ALF
//! sub-step errors, summed.  That signal scales as `O(h²)` — deliberately
//! *conservative* for a 4th-order method (the controller over-resolves
//! rather than under-resolves); the magnitude sum avoids sign
//! cancellation across the `γ₂ < 0` sub-step.
//!
//! Everything is composed from [`AlfSolver`]'s public ψ-kernel `_into`
//! entry points, so the fused native-dynamics hooks ride along
//! automatically and per-row batch arithmetic stays bitwise identical to
//! the solo methods (pinned in `tests/prop_solver.rs`).

use super::alf::AlfSolver;
use super::batch::{BatchSpec, BatchState};
use super::dynamics::Dynamics;
use super::workspace::{ensure, ensure_f64, fill_stage_times, BatchWorkspace, SolverWorkspace};
use super::{Solver, State};
use crate::tensor::Tensor;

/// `2^{1/3}` to f64 precision (written out so the triple-jump constants
/// are plain consts; `cbrt` is not a const fn).
const CBRT2: f64 = 1.259_921_049_894_873_2;
/// Outer sub-step weight `γ₁ = γ₃`.
const GAMMA1: f64 = 1.0 / (2.0 - CBRT2);
/// Middle (negative) sub-step weight `γ₂`.
const GAMMA2: f64 = -CBRT2 / (2.0 - CBRT2);
/// Sub-step sizes in units of the composed step `h`.
const GAMMAS: [f64; 3] = [GAMMA1, GAMMA2, GAMMA1];
/// Sub-step *start* times in units of `h` from the composed step's start.
const OFFSETS: [f64; 3] = [0.0, GAMMA1, GAMMA1 + GAMMA2];
/// Sub-step *end* times in units of `h` from the composed step's end
/// (`t_out + h·END_OFFSETS[i]` is where sub-step `i`'s output sits —
/// the anchor times of the reverse ψ⁻¹ chain).
const END_OFFSETS: [f64; 3] = [-(GAMMA2 + GAMMA1), -GAMMA1, 0.0];

/// Per-row sub-step sizes `h_b·γ` — the batched mirror of the solo
/// `h * GAMMAS[i]` arithmetic (same expression, so rows stay bitwise
/// equal to solo sub-steps).
fn fill_sub_hs(hs: &[f64], gamma: f64, out: &mut Vec<f64>) {
    ensure_f64(out, hs.len());
    for (o, &h) in out.iter_mut().zip(hs) {
        *o = h * gamma;
    }
}

/// The 4th-order reversible composition solver.  Wraps an [`AlfSolver`]
/// whose ψ/ψ⁻¹/ψ-vjp kernels perform every sub-step (and carry the fused
/// dynamics dispatch).
#[derive(Debug, Clone, Copy)]
pub struct Reversible4 {
    /// The ALF base map; `inner.eta == 1` for the 4th-order guarantee.
    pub inner: AlfSolver,
}

impl Reversible4 {
    pub fn new(eta: f64) -> Self {
        Reversible4 {
            inner: AlfSolver::new(eta),
        }
    }
}

fn empty_state() -> State {
    State {
        z: Vec::new(),
        v: None,
    }
}

fn empty_batch_state() -> BatchState {
    BatchState {
        z: Tensor::new(Vec::new(), vec![0, 0]),
        v: None,
    }
}

impl Solver for Reversible4 {
    fn name(&self) -> &'static str {
        if self.inner.eta == 1.0 {
            "reversible4"
        } else {
            "reversible4-damped"
        }
    }

    fn order(&self) -> usize {
        4
    }

    fn has_error_estimate(&self) -> bool {
        true
    }

    fn is_invertible(&self) -> bool {
        true
    }

    fn init(&self, dynamics: &dyn Dynamics, t0: f64, z0: &[f32]) -> State {
        // Same augmented initialisation as ALF: v₀ = f(z₀, t₀).
        self.inner.init(dynamics, t0, z0)
    }

    fn step(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s: &State,
    ) -> (State, Option<Vec<f32>>) {
        let mut ws = SolverWorkspace::new();
        let mut out = empty_state();
        let mut err = Vec::new();
        self.step_into(dynamics, t, h, s, &mut out, &mut err, &mut ws);
        (out, Some(err))
    }

    fn step_vjp(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s_in: &State,
        a_out: &State,
    ) -> (State, Vec<f32>) {
        let mut ws = SolverWorkspace::new();
        let mut a_in = empty_state();
        let mut ath = vec![0.0f32; dynamics.param_dim()];
        self.step_vjp_into(dynamics, t, h, s_in, a_out, &mut a_in, &mut ath, &mut ws);
        (a_in, ath)
    }

    fn invert(
        &self,
        dynamics: &dyn Dynamics,
        t_out: f64,
        h: f64,
        s_out: &State,
    ) -> Option<State> {
        let mut ws = SolverWorkspace::new();
        let mut out = empty_state();
        self.invert_into(dynamics, t_out, h, s_out, &mut out, &mut ws);
        Some(out)
    }

    fn invert_and_vjp(
        &self,
        dynamics: &dyn Dynamics,
        t_out: f64,
        h: f64,
        s_out: &State,
        a_out: &State,
    ) -> Option<(State, State, Vec<f32>)> {
        let mut ws = SolverWorkspace::new();
        let mut s_in = empty_state();
        let mut a_in = empty_state();
        let mut ath = vec![0.0f32; dynamics.param_dim()];
        self.invert_and_vjp_into(
            dynamics, t_out, h, s_out, a_out, &mut s_in, &mut a_in, &mut ath, &mut ws,
        );
        Some((s_in, a_in, ath))
    }

    // ---- workspace path --------------------------------------------------

    fn step_into(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s: &State,
        out: &mut State,
        err: &mut Vec<f32>,
        ws: &mut SolverWorkspace,
    ) -> bool {
        let v = s.v.as_ref().expect("reversible-4 needs augmented state (z, v)");
        let n = s.z.len();
        super::workspace::shape_state_n(out, n, true);
        ensure(err, n);
        let mut sa = ws.take_state(s);
        let mut sb = ws.take_state(s);
        let mut e = ws.take_err();
        ensure(&mut e, n);
        {
            let State { z: az, v: av } = &mut sa;
            let av = av.as_mut().expect("shaped from augmented template");
            self.inner.psi_into(
                dynamics,
                t + h * OFFSETS[0],
                h * GAMMAS[0],
                &s.z,
                v,
                az,
                av,
                err,
                ws,
            );
        }
        for x in err.iter_mut() {
            *x = x.abs();
        }
        {
            let sav = sa.v.as_deref().expect("shaped from augmented template");
            let State { z: bz, v: bv } = &mut sb;
            let bv = bv.as_mut().expect("shaped from augmented template");
            self.inner.psi_into(
                dynamics,
                t + h * OFFSETS[1],
                h * GAMMAS[1],
                &sa.z,
                sav,
                bz,
                bv,
                &mut e,
                ws,
            );
        }
        for (o, x) in err.iter_mut().zip(&e) {
            *o += x.abs();
        }
        {
            let sbv = sb.v.as_deref().expect("shaped from augmented template");
            let State { z: oz, v: ov } = out;
            let ov = ov.as_mut().expect("just shaped");
            self.inner.psi_into(
                dynamics,
                t + h * OFFSETS[2],
                h * GAMMAS[2],
                &sb.z,
                sbv,
                oz,
                ov,
                &mut e,
                ws,
            );
        }
        for (o, x) in err.iter_mut().zip(&e) {
            *o += x.abs();
        }
        ws.put_state(sa);
        ws.put_state(sb);
        ws.put_err(e);
        true
    }

    fn step_vjp_into(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s_in: &State,
        a_out: &State,
        a_in: &mut State,
        ath_acc: &mut [f32],
        ws: &mut SolverWorkspace,
    ) {
        let v = s_in.v.as_ref().expect("reversible-4 needs augmented state");
        let n = s_in.z.len();
        super::workspace::shape_state_n(a_in, n, true);
        // recompute the two interior sub-states from the stored input
        let mut sa = ws.take_state(s_in);
        let mut sb = ws.take_state(s_in);
        let mut e = ws.take_err();
        ensure(&mut e, n);
        {
            let State { z: az, v: av } = &mut sa;
            let av = av.as_mut().expect("shaped from augmented template");
            self.inner.psi_into(
                dynamics,
                t + h * OFFSETS[0],
                h * GAMMAS[0],
                &s_in.z,
                v,
                az,
                av,
                &mut e,
                ws,
            );
        }
        {
            let sav = sa.v.as_deref().expect("shaped from augmented template");
            let State { z: bz, v: bv } = &mut sb;
            let bv = bv.as_mut().expect("shaped from augmented template");
            self.inner.psi_into(
                dynamics,
                t + h * OFFSETS[1],
                h * GAMMAS[1],
                &sa.z,
                sav,
                bz,
                bv,
                &mut e,
                ws,
            );
        }
        // a_v(T) may be absent: substitute the workspace's zero cotangent
        let mut zero_buf = std::mem::take(&mut ws.zero);
        if a_out.v.is_none() {
            ensure(&mut zero_buf, n);
        }
        let av_out: &[f32] = match &a_out.v {
            Some(av) => av,
            None => &zero_buf,
        };
        // chain the sub-step vjps in reverse (3 → 2 → 1)
        let mut ac = ws.take_state(s_in);
        let mut ap = ws.take_state(s_in);
        {
            let sbv = sb.v.as_deref().expect("shaped from augmented template");
            let State { z: cz, v: cv } = &mut ac;
            let cv = cv.as_mut().expect("shaped from augmented template");
            self.inner.psi_vjp_into(
                dynamics,
                t + h * OFFSETS[2],
                h * GAMMAS[2],
                &sb.z,
                sbv,
                &a_out.z,
                av_out,
                cz,
                cv,
                ath_acc,
                ws,
            );
        }
        {
            let sav = sa.v.as_deref().expect("shaped from augmented template");
            let acv = ac.v.as_deref().expect("shaped from augmented template");
            let State { z: pz, v: pv } = &mut ap;
            let pv = pv.as_mut().expect("shaped from augmented template");
            self.inner.psi_vjp_into(
                dynamics,
                t + h * OFFSETS[1],
                h * GAMMAS[1],
                &sa.z,
                sav,
                &ac.z,
                acv,
                pz,
                pv,
                ath_acc,
                ws,
            );
        }
        {
            let apv = ap.v.as_deref().expect("shaped from augmented template");
            let State { z: iz, v: iv } = a_in;
            let iv = iv.as_mut().expect("just shaped");
            self.inner.psi_vjp_into(
                dynamics,
                t + h * OFFSETS[0],
                h * GAMMAS[0],
                &s_in.z,
                v,
                &ap.z,
                apv,
                iz,
                iv,
                ath_acc,
                ws,
            );
        }
        ws.zero = zero_buf;
        ws.put_state(sa);
        ws.put_state(sb);
        ws.put_state(ac);
        ws.put_state(ap);
        ws.put_err(e);
    }

    fn invert_into(
        &self,
        dynamics: &dyn Dynamics,
        t_out: f64,
        h: f64,
        s_out: &State,
        out: &mut State,
        ws: &mut SolverWorkspace,
    ) -> bool {
        let v = s_out.v.as_ref().expect("reversible-4 needs augmented state");
        let n = s_out.z.len();
        super::workspace::shape_state_n(out, n, true);
        let mut sb = ws.take_state(s_out);
        let mut sa = ws.take_state(s_out);
        {
            let State { z: bz, v: bv } = &mut sb;
            let bv = bv.as_mut().expect("shaped from augmented template");
            self.inner.psi_inv_into(
                dynamics,
                t_out + h * END_OFFSETS[2],
                h * GAMMAS[2],
                &s_out.z,
                v,
                bz,
                bv,
                ws,
            );
        }
        {
            let sbv = sb.v.as_deref().expect("shaped from augmented template");
            let State { z: az, v: av } = &mut sa;
            let av = av.as_mut().expect("shaped from augmented template");
            self.inner.psi_inv_into(
                dynamics,
                t_out + h * END_OFFSETS[1],
                h * GAMMAS[1],
                &sb.z,
                sbv,
                az,
                av,
                ws,
            );
        }
        {
            let sav = sa.v.as_deref().expect("shaped from augmented template");
            let State { z: oz, v: ov } = out;
            let ov = ov.as_mut().expect("just shaped");
            self.inner.psi_inv_into(
                dynamics,
                t_out + h * END_OFFSETS[0],
                h * GAMMAS[0],
                &sa.z,
                sav,
                oz,
                ov,
                ws,
            );
        }
        ws.put_state(sb);
        ws.put_state(sa);
        true
    }

    fn invert_and_vjp_into(
        &self,
        dynamics: &dyn Dynamics,
        t_out: f64,
        h: f64,
        s_out: &State,
        a_out: &State,
        s_in: &mut State,
        a_in: &mut State,
        ath_acc: &mut [f32],
        ws: &mut SolverWorkspace,
    ) -> bool {
        // Per-sub-step ψ⁻¹+vjp micro-steps, chained backward — each rides
        // the inner solver's fused bwd hook when the dynamics has one.
        let mut s1 = ws.take_state(s_out);
        let mut a1 = ws.take_state(s_out);
        let mut s2 = ws.take_state(s_out);
        let mut a2 = ws.take_state(s_out);
        self.inner.invert_and_vjp_into(
            dynamics,
            t_out + h * END_OFFSETS[2],
            h * GAMMAS[2],
            s_out,
            a_out,
            &mut s1,
            &mut a1,
            ath_acc,
            ws,
        );
        self.inner.invert_and_vjp_into(
            dynamics,
            t_out + h * END_OFFSETS[1],
            h * GAMMAS[1],
            &s1,
            &a1,
            &mut s2,
            &mut a2,
            ath_acc,
            ws,
        );
        self.inner.invert_and_vjp_into(
            dynamics,
            t_out + h * END_OFFSETS[0],
            h * GAMMAS[0],
            &s2,
            &a2,
            s_in,
            a_in,
            ath_acc,
            ws,
        );
        ws.put_state(s1);
        ws.put_state(a1);
        ws.put_state(s2);
        ws.put_state(a2);
        true
    }

    // ---- batched path ---------------------------------------------------

    fn init_batch(
        &self,
        dynamics: &dyn Dynamics,
        t0: f64,
        z0: &[f32],
        spec: &BatchSpec,
    ) -> BatchState {
        self.inner.init_batch(dynamics, t0, z0, spec)
    }

    fn init_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        t0: f64,
        z0: &[f32],
        spec: &BatchSpec,
        out: &mut BatchState,
        ws: &mut BatchWorkspace,
    ) {
        self.inner.init_batch_into(dynamics, t0, z0, spec, out, ws);
    }

    fn step_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s: &BatchState,
    ) -> (BatchState, Option<Vec<f32>>) {
        let mut ws = BatchWorkspace::new();
        let mut out = empty_batch_state();
        let mut err = Vec::new();
        self.step_batch_into(dynamics, ts, hs, s, &mut out, &mut err, &mut ws);
        (out, Some(err))
    }

    fn step_vjp_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s_in: &BatchState,
        a_out: &BatchState,
    ) -> (BatchState, Vec<f32>) {
        let mut ws = BatchWorkspace::new();
        let mut a_in = empty_batch_state();
        let mut ath = vec![0.0f32; dynamics.param_dim()];
        self.step_vjp_batch_into(dynamics, ts, hs, s_in, a_out, &mut a_in, &mut ath, &mut ws);
        (a_in, ath)
    }

    fn invert_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts_out: &[f64],
        hs: &[f64],
        s_out: &BatchState,
    ) -> Option<BatchState> {
        let mut ws = BatchWorkspace::new();
        let mut out = empty_batch_state();
        self.invert_batch_into(dynamics, ts_out, hs, s_out, &mut out, &mut ws);
        Some(out)
    }

    // ---- batched workspace path -----------------------------------------

    fn step_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s: &BatchState,
        out: &mut BatchState,
        err: &mut Vec<f32>,
        ws: &mut BatchWorkspace,
    ) -> bool {
        let spec = s.spec();
        let v = s.v.as_ref().expect("reversible-4 needs augmented state (z, v)");
        super::workspace::shape_batch_state(out, spec.batch, spec.n_z, true);
        ensure(err, spec.flat_len());
        let mut sub_ts = std::mem::take(&mut ws.sub_ts);
        let mut sub_hs = std::mem::take(&mut ws.sub_hs);
        let mut sa = ws.take_batch(spec.batch, spec.n_z, true);
        let mut sb = ws.take_batch(spec.batch, spec.n_z, true);
        let mut e = ws.take_err();
        ensure(&mut e, spec.flat_len());
        fill_stage_times(ts, hs, OFFSETS[0], &mut sub_ts);
        fill_sub_hs(hs, GAMMAS[0], &mut sub_hs);
        {
            let BatchState { z: az, v: av } = &mut sa;
            let av = av.as_mut().expect("just shaped");
            self.inner.psi_batch_into(
                dynamics,
                &sub_ts,
                &sub_hs,
                &s.z.data,
                &v.data,
                &spec,
                &mut az.data,
                &mut av.data,
                err,
                ws,
            );
        }
        for x in err.iter_mut() {
            *x = x.abs();
        }
        fill_stage_times(ts, hs, OFFSETS[1], &mut sub_ts);
        fill_sub_hs(hs, GAMMAS[1], &mut sub_hs);
        {
            let sav = sa.v.as_ref().expect("just shaped");
            let BatchState { z: bz, v: bv } = &mut sb;
            let bv = bv.as_mut().expect("just shaped");
            self.inner.psi_batch_into(
                dynamics,
                &sub_ts,
                &sub_hs,
                &sa.z.data,
                &sav.data,
                &spec,
                &mut bz.data,
                &mut bv.data,
                &mut e,
                ws,
            );
        }
        for (o, x) in err.iter_mut().zip(&e) {
            *o += x.abs();
        }
        fill_stage_times(ts, hs, OFFSETS[2], &mut sub_ts);
        fill_sub_hs(hs, GAMMAS[2], &mut sub_hs);
        {
            let sbv = sb.v.as_ref().expect("just shaped");
            let BatchState { z: oz, v: ov } = out;
            let ov = ov.as_mut().expect("just shaped");
            self.inner.psi_batch_into(
                dynamics,
                &sub_ts,
                &sub_hs,
                &sb.z.data,
                &sbv.data,
                &spec,
                &mut oz.data,
                &mut ov.data,
                &mut e,
                ws,
            );
        }
        for (o, x) in err.iter_mut().zip(&e) {
            *o += x.abs();
        }
        ws.sub_ts = sub_ts;
        ws.sub_hs = sub_hs;
        ws.put_batch(sa);
        ws.put_batch(sb);
        ws.put_err(e);
        true
    }

    fn step_vjp_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s_in: &BatchState,
        a_out: &BatchState,
        a_in: &mut BatchState,
        ath_acc: &mut [f32],
        ws: &mut BatchWorkspace,
    ) {
        let spec = s_in.spec();
        let v = s_in.v.as_ref().expect("reversible-4 needs augmented state");
        super::workspace::shape_batch_state(a_in, spec.batch, spec.n_z, true);
        let mut sub_ts = std::mem::take(&mut ws.sub_ts);
        let mut sub_hs = std::mem::take(&mut ws.sub_hs);
        // recompute the two interior sub-states
        let mut sa = ws.take_batch(spec.batch, spec.n_z, true);
        let mut sb = ws.take_batch(spec.batch, spec.n_z, true);
        let mut e = ws.take_err();
        ensure(&mut e, spec.flat_len());
        fill_stage_times(ts, hs, OFFSETS[0], &mut sub_ts);
        fill_sub_hs(hs, GAMMAS[0], &mut sub_hs);
        {
            let BatchState { z: az, v: av } = &mut sa;
            let av = av.as_mut().expect("just shaped");
            self.inner.psi_batch_into(
                dynamics,
                &sub_ts,
                &sub_hs,
                &s_in.z.data,
                &v.data,
                &spec,
                &mut az.data,
                &mut av.data,
                &mut e,
                ws,
            );
        }
        fill_stage_times(ts, hs, OFFSETS[1], &mut sub_ts);
        fill_sub_hs(hs, GAMMAS[1], &mut sub_hs);
        {
            let sav = sa.v.as_ref().expect("just shaped");
            let BatchState { z: bz, v: bv } = &mut sb;
            let bv = bv.as_mut().expect("just shaped");
            self.inner.psi_batch_into(
                dynamics,
                &sub_ts,
                &sub_hs,
                &sa.z.data,
                &sav.data,
                &spec,
                &mut bz.data,
                &mut bv.data,
                &mut e,
                ws,
            );
        }
        // a_v(T) may be absent: substitute the zero cotangent
        let mut zero_buf = std::mem::take(&mut ws.zero);
        if a_out.v.is_none() {
            ensure(&mut zero_buf, spec.flat_len());
        }
        let av_out: &[f32] = match &a_out.v {
            Some(av) => &av.data,
            None => &zero_buf,
        };
        // chain the sub-step vjps in reverse (3 → 2 → 1)
        let mut ac = ws.take_batch(spec.batch, spec.n_z, true);
        let mut ap = ws.take_batch(spec.batch, spec.n_z, true);
        fill_stage_times(ts, hs, OFFSETS[2], &mut sub_ts);
        fill_sub_hs(hs, GAMMAS[2], &mut sub_hs);
        {
            let sbv = sb.v.as_ref().expect("just shaped");
            let BatchState { z: cz, v: cv } = &mut ac;
            let cv = cv.as_mut().expect("just shaped");
            self.inner.psi_vjp_batch_into(
                dynamics,
                &sub_ts,
                &sub_hs,
                &sb.z.data,
                &sbv.data,
                &a_out.z.data,
                av_out,
                &spec,
                &mut cz.data,
                &mut cv.data,
                ath_acc,
                ws,
            );
        }
        fill_stage_times(ts, hs, OFFSETS[1], &mut sub_ts);
        fill_sub_hs(hs, GAMMAS[1], &mut sub_hs);
        {
            let sav = sa.v.as_ref().expect("just shaped");
            let acv = ac.v.as_ref().expect("just shaped");
            let BatchState { z: pz, v: pv } = &mut ap;
            let pv = pv.as_mut().expect("just shaped");
            self.inner.psi_vjp_batch_into(
                dynamics,
                &sub_ts,
                &sub_hs,
                &sa.z.data,
                &sav.data,
                &ac.z.data,
                &acv.data,
                &spec,
                &mut pz.data,
                &mut pv.data,
                ath_acc,
                ws,
            );
        }
        fill_stage_times(ts, hs, OFFSETS[0], &mut sub_ts);
        fill_sub_hs(hs, GAMMAS[0], &mut sub_hs);
        {
            let apv = ap.v.as_ref().expect("just shaped");
            let BatchState { z: iz, v: iv } = a_in;
            let iv = iv.as_mut().expect("just shaped");
            self.inner.psi_vjp_batch_into(
                dynamics,
                &sub_ts,
                &sub_hs,
                &s_in.z.data,
                &v.data,
                &ap.z.data,
                &apv.data,
                &spec,
                &mut iz.data,
                &mut iv.data,
                ath_acc,
                ws,
            );
        }
        ws.zero = zero_buf;
        ws.sub_ts = sub_ts;
        ws.sub_hs = sub_hs;
        ws.put_batch(sa);
        ws.put_batch(sb);
        ws.put_batch(ac);
        ws.put_batch(ap);
        ws.put_err(e);
    }

    fn invert_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        ts_out: &[f64],
        hs: &[f64],
        s_out: &BatchState,
        out: &mut BatchState,
        ws: &mut BatchWorkspace,
    ) -> bool {
        let spec = s_out.spec();
        let v = s_out.v.as_ref().expect("reversible-4 needs augmented state");
        super::workspace::shape_batch_state(out, spec.batch, spec.n_z, true);
        let mut sub_ts = std::mem::take(&mut ws.sub_ts);
        let mut sub_hs = std::mem::take(&mut ws.sub_hs);
        let mut sb = ws.take_batch(spec.batch, spec.n_z, true);
        let mut sa = ws.take_batch(spec.batch, spec.n_z, true);
        fill_stage_times(ts_out, hs, END_OFFSETS[2], &mut sub_ts);
        fill_sub_hs(hs, GAMMAS[2], &mut sub_hs);
        {
            let BatchState { z: bz, v: bv } = &mut sb;
            let bv = bv.as_mut().expect("just shaped");
            self.inner.psi_inv_batch_into(
                dynamics,
                &sub_ts,
                &sub_hs,
                &s_out.z.data,
                &v.data,
                &spec,
                &mut bz.data,
                &mut bv.data,
                ws,
            );
        }
        fill_stage_times(ts_out, hs, END_OFFSETS[1], &mut sub_ts);
        fill_sub_hs(hs, GAMMAS[1], &mut sub_hs);
        {
            let sbv = sb.v.as_ref().expect("just shaped");
            let BatchState { z: az, v: av } = &mut sa;
            let av = av.as_mut().expect("just shaped");
            self.inner.psi_inv_batch_into(
                dynamics,
                &sub_ts,
                &sub_hs,
                &sb.z.data,
                &sbv.data,
                &spec,
                &mut az.data,
                &mut av.data,
                ws,
            );
        }
        fill_stage_times(ts_out, hs, END_OFFSETS[0], &mut sub_ts);
        fill_sub_hs(hs, GAMMAS[0], &mut sub_hs);
        {
            let sav = sa.v.as_ref().expect("just shaped");
            let BatchState { z: oz, v: ov } = out;
            let ov = ov.as_mut().expect("just shaped");
            self.inner.psi_inv_batch_into(
                dynamics,
                &sub_ts,
                &sub_hs,
                &sa.z.data,
                &sav.data,
                &spec,
                &mut oz.data,
                &mut ov.data,
                ws,
            );
        }
        ws.sub_ts = sub_ts;
        ws.sub_hs = sub_hs;
        ws.put_batch(sb);
        ws.put_batch(sa);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::dynamics::{LinearToy, MlpDynamics};
    use crate::util::rng::Rng;

    #[test]
    fn triple_jump_coefficients_sum_to_one() {
        let sum: f64 = GAMMAS.iter().sum();
        assert!((sum - 1.0).abs() < 1e-14, "{sum}");
        // the sub-step start/end offsets agree: the last sub-step ends at h
        assert!((OFFSETS[2] + GAMMAS[2] - 1.0).abs() < 1e-14);
        for i in 0..3 {
            assert!(
                (OFFSETS[i] + GAMMAS[i] - (1.0 + END_OFFSETS[i])).abs() < 1e-14,
                "sub-step {i} start+size must equal its end offset"
            );
        }
    }

    /// One composed step beats ALF's O(h³) local error decisively: halving
    /// h cuts the one-step error by ≳2⁴ (the dominant local term is O(h⁴)
    /// from the v-channel; successive steps cancel it telescopically,
    /// which is where the global 4th order comes from — pinned in
    /// `tests/solver_properties.rs`).
    #[test]
    fn local_truncation_beats_alf() {
        let toy = LinearToy::new(1.0, 1);
        let solver = Reversible4::new(1.0);
        let z0 = [1.0f32];
        let mut errs = Vec::new();
        for &h in &[0.4f64, 0.2, 0.1] {
            let s0 = solver.init(&toy, 0.0, &z0);
            let (s1, _) = solver.step(&toy, 0.0, h, &s0);
            let exact = h.exp() as f32;
            errs.push(((s1.z[0] - exact).abs()) as f64);
        }
        for w in errs.windows(2) {
            let ratio = w[0] / w[1].max(1e-300);
            assert!(ratio > 12.0, "expected ≳16x decay, got {ratio} ({errs:?})");
        }
    }

    /// Ψ⁻¹(Ψ(x)) = x to float roundoff — the exact algebraic inverse the
    /// constant-memory reverse sweep rests on, inherited sub-step by
    /// sub-step from ALF.
    #[test]
    fn composed_inverse_roundtrip() {
        let mut rng = Rng::new(11);
        let dynamics = MlpDynamics::new(6, 8, &mut rng);
        for &eta in &[1.0, 0.9] {
            let solver = Reversible4::new(eta);
            let z: Vec<f32> = (0..6).map(|i| 0.2 * i as f32 - 0.5).collect();
            let s0 = solver.init(&dynamics, 0.3, &z);
            let (s1, _) = solver.step(&dynamics, 0.3, 0.17, &s0);
            let s0b = solver.invert(&dynamics, 0.3 + 0.17, 0.17, &s1).unwrap();
            let v0 = s0.v.as_ref().unwrap();
            let v0b = s0b.v.as_ref().unwrap();
            for i in 0..6 {
                assert!(
                    (s0b.z[i] - s0.z[i]).abs() < 1e-4,
                    "eta {eta} z[{i}]: {} vs {}",
                    s0b.z[i],
                    s0.z[i]
                );
                assert!((v0b[i] - v0[i]).abs() < 1e-4, "eta {eta} v[{i}]");
            }
        }
    }

    /// vjp of the composed step matches central finite differences on
    /// (z, v, θ) — the chained sub-step vjps are the true adjoint of the
    /// chained sub-steps.
    #[test]
    fn composed_vjp_matches_finite_differences() {
        let mut rng = Rng::new(13);
        let mut dynamics = MlpDynamics::new(3, 5, &mut rng);
        let solver = Reversible4::new(1.0);
        let (t, h) = (0.1, 0.2);
        let z: Vec<f32> = vec![0.3, -0.2, 0.5];
        let v = crate::solvers::dynamics::Dynamics::f(&dynamics, t, &z);
        let az_out: Vec<f32> = vec![1.0, -0.5, 0.25];
        let av_out: Vec<f32> = vec![0.2, 0.4, -0.3];
        let s_in = State {
            z: z.clone(),
            v: Some(v.clone()),
        };
        let a_out = State {
            z: az_out.clone(),
            v: Some(av_out.clone()),
        };
        let (a_in, a_th) = solver.step_vjp(&dynamics, t, h, &s_in, &a_out);
        let a_z = &a_in.z;
        let a_v = a_in.v.as_ref().unwrap();

        let scalar = |zz: &[f32], vv: &[f32], d: &MlpDynamics| -> f64 {
            let s = State {
                z: zz.to_vec(),
                v: Some(vv.to_vec()),
            };
            let (s1, _) = solver.step(d, t, h, &s);
            s1.z
                .iter()
                .zip(&az_out)
                .chain(s1.v.as_ref().unwrap().iter().zip(&av_out))
                .map(|(&x, &c)| x as f64 * c as f64)
                .sum()
        };
        let eps = 1e-3;
        for j in 0..z.len() {
            let mut zp = z.clone();
            zp[j] += eps as f32;
            let mut zm = z.clone();
            zm[j] -= eps as f32;
            let fd = (scalar(&zp, &v, &dynamics) - scalar(&zm, &v, &dynamics)) / (2.0 * eps);
            assert!(
                (fd - a_z[j] as f64).abs() < 1e-2,
                "a_z[{j}]: {fd} vs {}",
                a_z[j]
            );
        }
        for j in 0..v.len() {
            let mut vp = v.clone();
            vp[j] += eps as f32;
            let mut vm = v.clone();
            vm[j] -= eps as f32;
            let fd = (scalar(&z, &vp, &dynamics) - scalar(&z, &vm, &dynamics)) / (2.0 * eps);
            assert!(
                (fd - a_v[j] as f64).abs() < 1e-2,
                "a_v[{j}]: {fd} vs {}",
                a_v[j]
            );
        }
        let theta0 = dynamics.params().to_vec();
        for &k in &[0usize, 7, theta0.len() - 1] {
            let mut tp = theta0.clone();
            tp[k] += eps as f32;
            dynamics.set_params(&tp);
            let fp = scalar(&z, &v, &dynamics);
            let mut tm = theta0.clone();
            tm[k] -= eps as f32;
            dynamics.set_params(&tm);
            let fm = scalar(&z, &v, &dynamics);
            dynamics.set_params(&theta0);
            let fd = (fp - fm) / (2.0 * eps);
            assert!(
                (fd - a_th[k] as f64).abs() < 1e-2,
                "a_θ[{k}]: {fd} vs {}",
                a_th[k]
            );
        }
    }

    /// Batched composed step/vjp/inverse with desynchronized per-row
    /// `(t, h)` equals the single-sample methods row-for-row (bitwise) —
    /// the same invariant ALF pins, now through the composition layer.
    #[test]
    fn batched_composition_matches_rows_exactly() {
        let mut rng = Rng::new(17);
        let dynamics = MlpDynamics::new(3, 5, &mut rng);
        let solver = Reversible4::new(1.0);
        let spec = BatchSpec::new(3, 3);
        let mut z = vec![0.0f32; spec.flat_len()];
        rng.fill_normal(&mut z, 0.5);
        let ts = [0.0, 0.3, 0.7];
        let hs = [0.1, 0.25, 0.05];
        let v = dynamics.f_batch(&ts, &z, &spec);
        let s = BatchState::from_flat_zv(z.clone(), v.clone(), spec);

        let (s_next, err) = solver.step_batch(&dynamics, &ts, &hs, &s);
        let err = err.expect("reversible-4 has an error estimate");
        for b in 0..3 {
            let row = State {
                z: spec.row(&z, b).to_vec(),
                v: Some(spec.row(&v, b).to_vec()),
            };
            let (rs, re) = solver.step(&dynamics, ts[b], hs[b], &row);
            assert_eq!(spec.row(&s_next.z.data, b), rs.z.as_slice(), "z row {b}");
            assert_eq!(
                spec.row(&s_next.v.as_ref().unwrap().data, b),
                rs.v.as_ref().unwrap().as_slice(),
                "v row {b}"
            );
            assert_eq!(spec.row(&err, b), re.unwrap().as_slice(), "err row {b}");
        }

        // batched inverse row-equality + roundtrip
        let ts_out: Vec<f64> = ts.iter().zip(&hs).map(|(&t, &h)| t + h).collect();
        let s_back = solver
            .invert_batch(&dynamics, &ts_out, &hs, &s_next)
            .expect("reversible-4 is invertible");
        for b in 0..3 {
            let row = State {
                z: spec.row(&s_next.z.data, b).to_vec(),
                v: Some(spec.row(&s_next.v.as_ref().unwrap().data, b).to_vec()),
            };
            let rs = solver.invert(&dynamics, ts_out[b], hs[b], &row).unwrap();
            assert_eq!(spec.row(&s_back.z.data, b), rs.z.as_slice(), "inv z row {b}");
        }
        for i in 0..spec.flat_len() {
            assert!((s_back.z.data[i] - z[i]).abs() < 1e-4, "roundtrip z[{i}]");
        }

        // batched vjp row-equality (θ sums over rows)
        let mut az = vec![0.0f32; spec.flat_len()];
        let mut av = vec![0.0f32; spec.flat_len()];
        rng.fill_normal(&mut az, 1.0);
        rng.fill_normal(&mut av, 1.0);
        let a_out = BatchState::from_flat_zv(az.clone(), av.clone(), spec);
        let (a_in, ath) = solver.step_vjp_batch(&dynamics, &ts, &hs, &s, &a_out);
        let mut ath_sum = vec![0.0f32; dynamics.param_dim()];
        for b in 0..3 {
            let row_s = State {
                z: spec.row(&z, b).to_vec(),
                v: Some(spec.row(&v, b).to_vec()),
            };
            let row_a = State {
                z: spec.row(&az, b).to_vec(),
                v: Some(spec.row(&av, b).to_vec()),
            };
            let (ra, rth) = solver.step_vjp(&dynamics, ts[b], hs[b], &row_s, &row_a);
            assert_eq!(spec.row(&a_in.z.data, b), ra.z.as_slice(), "a_z row {b}");
            assert_eq!(
                spec.row(&a_in.v.as_ref().unwrap().data, b),
                ra.v.as_ref().unwrap().as_slice(),
                "a_v row {b}"
            );
            crate::tensor::axpy(1.0, &rth, &mut ath_sum);
        }
        for (k, (&got, &want)) in ath.iter().zip(&ath_sum).enumerate() {
            assert!((got - want).abs() < 1e-4, "a_θ[{k}]: {got} vs {want}");
        }
    }
}
