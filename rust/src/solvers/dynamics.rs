//! The [`Dynamics`] abstraction: the ODE right-hand side `f(t, z; θ)` and
//! its vector-Jacobian products.
//!
//! Two families implement it:
//! * native implementations in this file ([`LinearToy`], [`MlpDynamics`],
//!   [`ComplexEigenDynamics`]) — closed-form or small hand-differentiated
//!   models used by the toy experiment (paper Fig. 4) and by the
//!   property-test suite;
//! * `runtime::HloDynamics` — batched model graphs AOT-compiled from JAX
//!   (L2) containing the Pallas kernels (L1), used by every real experiment.
//!
//! Gradient methods compose everything they need (ψ, ψ⁻¹, ψ-vjp, the
//! adjoint's augmented dynamics) from `f` and `f_vjp`, so a single trait
//! covers all four estimation protocols.  Fused per-step executables (the
//! Pallas `alf_step` path) are an optional fast path — see
//! [`Dynamics::fused_alf`].

use std::cell::Cell;

/// Evaluation counters, used by the Table-1 complexity validation and the
/// computation-cost columns of the benches.
#[derive(Debug, Default, Clone)]
pub struct EvalCounters {
    /// Number of `f(t, z)` evaluations since the last reset.
    pub f_evals: Cell<u64>,
    /// Number of `f_vjp` evaluations since the last reset.
    pub vjp_evals: Cell<u64>,
}

impl EvalCounters {
    /// Zero both counters (called at the start of each gradient pass).
    pub fn reset(&self) {
        self.f_evals.set(0);
        self.vjp_evals.set(0);
    }
}

/// ODE right-hand side with parameters.
pub trait Dynamics {
    /// Flattened state dimension (batch × features for batched models).
    fn dim(&self) -> usize;

    /// Flattened parameter dimension of θ_f.
    fn param_dim(&self) -> usize;

    /// Evaluate `dz/dt = f(t, z; θ)`.
    fn f(&self, t: f64, z: &[f32]) -> Vec<f32>;

    /// Vector-Jacobian products: given cotangent `a`, return
    /// `(aᵀ ∂f/∂z, aᵀ ∂f/∂θ)`.
    fn f_vjp(&self, t: f64, z: &[f32], a: &[f32]) -> (Vec<f32>, Vec<f32>);

    /// The flat parameter vector θ_f.
    fn params(&self) -> &[f32];

    /// Replace θ_f (length must match [`Dynamics::param_dim`]).
    fn set_params(&mut self, theta: &[f32]);

    /// Evaluation counters used by the Table-1 cost accounting.
    fn counters(&self) -> &EvalCounters;

    /// Number of "layers" N_f for Table-1 style accounting (1 for toy).
    fn depth_nf(&self) -> usize {
        1
    }

    /// Optional fused damped-ALF step ψ executed device-side in one call
    /// (the L1 Pallas kernel path).  Returns `(z_out, v_out, err_embedded)`.
    /// Default: `None`, and the solver composes the step from [`Dynamics::f`].
    fn fused_alf(
        &self,
        _z: &[f32],
        _v: &[f32],
        _t: f64,
        _h: f64,
        _eta: f64,
    ) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        None
    }

    /// Optional fused ψ⁻¹ (see [`Dynamics::fused_alf`]); returns `(z_in, v_in)`.
    fn fused_alf_inv(
        &self,
        _z: &[f32],
        _v: &[f32],
        _t_out: f64,
        _h: f64,
        _eta: f64,
    ) -> Option<(Vec<f32>, Vec<f32>)> {
        None
    }

    /// Optional fused ψ-vjp; returns `(a_z, a_v, a_θ)` for cotangents on the
    /// step outputs.
    #[allow(clippy::too_many_arguments)]
    fn fused_alf_vjp(
        &self,
        _z: &[f32],
        _v: &[f32],
        _t: f64,
        _h: f64,
        _eta: f64,
        _az_out: &[f32],
        _av_out: &[f32],
    ) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        None
    }

    /// Optional fused MALI backward micro-step: ψ⁻¹ reconstruction *and*
    /// the vjp through ψ at the reconstructed point, in one device call —
    /// halves the backward pass's PJRT round-trips.  Inputs are the step
    /// *outputs* `(z_out, v_out)` at `t_out` and the output cotangents;
    /// returns `(z_in, v_in, a_z, a_v, a_θ)`.
    #[allow(clippy::too_many_arguments)]
    fn fused_alf_bwd(
        &self,
        _z_out: &[f32],
        _v_out: &[f32],
        _t_out: f64,
        _h: f64,
        _eta: f64,
        _az_out: &[f32],
        _av_out: &[f32],
    ) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        None
    }
}

// ---------------------------------------------------------------------------
// Native dynamics #1: the paper's toy problem  dz/dt = α z  (Eq. 6).
// ---------------------------------------------------------------------------

/// `dz/dt = α z` with θ = [α].  Every quantity in paper Eq. (7) has a closed
/// form, so this is the reference for gradient-error measurements (Fig. 4).
#[derive(Debug)]
pub struct LinearToy {
    pub alpha: Vec<f32>, // length-1 param vector
    pub n: usize,
    counters: EvalCounters,
}

impl LinearToy {
    pub fn new(alpha: f64, n: usize) -> Self {
        LinearToy {
            alpha: vec![alpha as f32],
            n,
            counters: EvalCounters::default(),
        }
    }

    pub fn analytic_z(&self, z0: &[f32], t: f64) -> Vec<f32> {
        let a = self.alpha[0] as f64;
        z0.iter().map(|&z| (z as f64 * (a * t).exp()) as f32).collect()
    }

    /// Analytic `dL/dz0` and `dL/dα` for `L = z(T)²` (summed over
    /// components), per paper Eq. (7).
    pub fn analytic_grads(&self, z0: &[f32], t_end: f64) -> (Vec<f32>, f64) {
        let a = self.alpha[0] as f64;
        let e = (2.0 * a * t_end).exp();
        let dz0: Vec<f32> = z0.iter().map(|&z| (2.0 * z as f64 * e) as f32).collect();
        let dalpha: f64 = z0
            .iter()
            .map(|&z| 2.0 * t_end * (z as f64) * (z as f64) * e)
            .sum();
        (dz0, dalpha)
    }
}

impl Dynamics for LinearToy {
    fn dim(&self) -> usize {
        self.n
    }

    fn param_dim(&self) -> usize {
        1
    }

    fn f(&self, _t: f64, z: &[f32]) -> Vec<f32> {
        self.counters.f_evals.set(self.counters.f_evals.get() + 1);
        let a = self.alpha[0];
        z.iter().map(|&zi| a * zi).collect()
    }

    fn f_vjp(&self, _t: f64, z: &[f32], a: &[f32]) -> (Vec<f32>, Vec<f32>) {
        self.counters.vjp_evals.set(self.counters.vjp_evals.get() + 1);
        let alpha = self.alpha[0];
        let az: Vec<f32> = a.iter().map(|&ai| alpha * ai).collect();
        let datheta: f64 = a
            .iter()
            .zip(z)
            .map(|(&ai, &zi)| ai as f64 * zi as f64)
            .sum();
        (az, vec![datheta as f32])
    }

    fn params(&self) -> &[f32] {
        &self.alpha
    }

    fn set_params(&mut self, theta: &[f32]) {
        self.alpha.copy_from_slice(theta);
    }

    fn counters(&self) -> &EvalCounters {
        &self.counters
    }
}

// ---------------------------------------------------------------------------
// Native dynamics #2: small MLP  f(t, z) = W2 · tanh(W1 z + b1) + b2
// with hand-written vjp — the finite-difference anchor for every gradient
// method in the property-test suite.
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct MlpDynamics {
    pub d: usize,
    pub hidden: usize,
    /// θ layout: [W1 (h×d) | b1 (h) | W2 (d×h) | b2 (d)]
    theta: Vec<f32>,
    counters: EvalCounters,
}

impl MlpDynamics {
    pub fn new(d: usize, hidden: usize, rng: &mut crate::util::rng::Rng) -> Self {
        let n = hidden * d + hidden + d * hidden + d;
        let mut theta = vec![0.0f32; n];
        // modest init so trajectories stay tame over T ~ 1
        rng.fill_normal(&mut theta, 0.4 / (d.max(hidden) as f64).sqrt());
        MlpDynamics {
            d,
            hidden,
            theta,
            counters: EvalCounters::default(),
        }
    }

    fn split(&self) -> (&[f32], &[f32], &[f32], &[f32]) {
        let (d, h) = (self.d, self.hidden);
        let w1 = &self.theta[0..h * d];
        let b1 = &self.theta[h * d..h * d + h];
        let w2 = &self.theta[h * d + h..h * d + h + d * h];
        let b2 = &self.theta[h * d + h + d * h..];
        (w1, b1, w2, b2)
    }
}

impl Dynamics for MlpDynamics {
    fn dim(&self) -> usize {
        self.d
    }

    fn param_dim(&self) -> usize {
        self.theta.len()
    }

    fn f(&self, _t: f64, z: &[f32]) -> Vec<f32> {
        self.counters.f_evals.set(self.counters.f_evals.get() + 1);
        let (w1, b1, w2, b2) = self.split();
        let (d, h) = (self.d, self.hidden);
        let mut hid = vec![0.0f32; h];
        for i in 0..h {
            let mut acc = b1[i];
            for j in 0..d {
                acc += w1[i * d + j] * z[j];
            }
            hid[i] = acc.tanh();
        }
        let mut out = vec![0.0f32; d];
        for i in 0..d {
            let mut acc = b2[i];
            for j in 0..h {
                acc += w2[i * h + j] * hid[j];
            }
            out[i] = acc;
        }
        out
    }

    fn f_vjp(&self, _t: f64, z: &[f32], a: &[f32]) -> (Vec<f32>, Vec<f32>) {
        self.counters.vjp_evals.set(self.counters.vjp_evals.get() + 1);
        let (w1, b1, w2, _b2) = self.split();
        let (d, h) = (self.d, self.hidden);
        // forward intermediates
        let mut pre = vec![0.0f32; h];
        for i in 0..h {
            let mut acc = b1[i];
            for j in 0..d {
                acc += w1[i * d + j] * z[j];
            }
            pre[i] = acc;
        }
        let hid: Vec<f32> = pre.iter().map(|p| p.tanh()).collect();
        // backward
        // out_i = b2_i + Σ_j w2[i,j] hid_j  with cotangent a_i
        let mut d_hid = vec![0.0f32; h];
        let mut d_w2 = vec![0.0f32; d * h];
        let d_b2 = a.to_vec();
        for i in 0..d {
            for j in 0..h {
                d_w2[i * h + j] = a[i] * hid[j];
                d_hid[j] += a[i] * w2[i * h + j];
            }
        }
        // hid_j = tanh(pre_j)
        let d_pre: Vec<f32> = d_hid
            .iter()
            .zip(&hid)
            .map(|(&dh, &t)| dh * (1.0 - t * t))
            .collect();
        let mut d_w1 = vec![0.0f32; h * d];
        let d_b1 = d_pre.clone();
        let mut d_z = vec![0.0f32; d];
        for i in 0..h {
            for j in 0..d {
                d_w1[i * d + j] = d_pre[i] * z[j];
                d_z[j] += d_pre[i] * w1[i * d + j];
            }
        }
        let mut d_theta = Vec::with_capacity(self.theta.len());
        d_theta.extend_from_slice(&d_w1);
        d_theta.extend_from_slice(&d_b1);
        d_theta.extend_from_slice(&d_w2);
        d_theta.extend_from_slice(&d_b2);
        (d_z, d_theta)
    }

    fn params(&self) -> &[f32] {
        &self.theta
    }

    fn set_params(&mut self, theta: &[f32]) {
        self.theta.copy_from_slice(theta);
    }

    fn counters(&self) -> &EvalCounters {
        &self.counters
    }

    fn depth_nf(&self) -> usize {
        2
    }
}

// ---------------------------------------------------------------------------
// Native dynamics #3: stiff linear test  dz/dt = σ z  with complex-σ
// behaviour emulated by 2×2 rotation blocks — used by the stability tests.
// ---------------------------------------------------------------------------

/// Block-diagonal linear dynamics: each 2×2 block is `[[re, -im], [im, re]]`,
/// i.e. eigenvalues `re ± i·im` — lets tests place Jacobian eigenvalues
/// anywhere on the complex plane (Theorem 3.2).
#[derive(Debug)]
pub struct ComplexEigenDynamics {
    /// (re, im) per block; θ is empty (not trained).
    pub eigs: Vec<(f32, f32)>,
    counters: EvalCounters,
    empty: Vec<f32>,
}

impl ComplexEigenDynamics {
    pub fn new(eigs: Vec<(f32, f32)>) -> Self {
        ComplexEigenDynamics {
            eigs,
            counters: EvalCounters::default(),
            empty: Vec::new(),
        }
    }
}

impl Dynamics for ComplexEigenDynamics {
    fn dim(&self) -> usize {
        self.eigs.len() * 2
    }

    fn param_dim(&self) -> usize {
        0
    }

    fn f(&self, _t: f64, z: &[f32]) -> Vec<f32> {
        self.counters.f_evals.set(self.counters.f_evals.get() + 1);
        let mut out = vec![0.0f32; z.len()];
        for (b, &(re, im)) in self.eigs.iter().enumerate() {
            let (x, y) = (z[2 * b], z[2 * b + 1]);
            out[2 * b] = re * x - im * y;
            out[2 * b + 1] = im * x + re * y;
        }
        out
    }

    fn f_vjp(&self, _t: f64, z: &[f32], a: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let _ = z;
        self.counters.vjp_evals.set(self.counters.vjp_evals.get() + 1);
        // Jᵀ a for the block structure
        let mut az = vec![0.0f32; a.len()];
        for (b, &(re, im)) in self.eigs.iter().enumerate() {
            let (ax, ay) = (a[2 * b], a[2 * b + 1]);
            az[2 * b] = re * ax + im * ay;
            az[2 * b + 1] = -im * ax + re * ay;
        }
        (az, Vec::new())
    }

    fn params(&self) -> &[f32] {
        &self.empty
    }

    fn set_params(&mut self, _theta: &[f32]) {}

    fn counters(&self) -> &EvalCounters {
        &self.counters
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn toy_matches_analytic_derivative() {
        let toy = LinearToy::new(0.5, 3);
        let z = [1.0f32, 2.0, -1.0];
        let fz = toy.f(0.0, &z);
        assert_eq!(fz, vec![0.5, 1.0, -0.5]);
        let (az, dth) = toy.f_vjp(0.0, &z, &[1.0, 1.0, 1.0]);
        assert_eq!(az, vec![0.5, 0.5, 0.5]);
        // dθ = Σ a_i z_i = 1 + 2 - 1 = 2
        assert!((dth[0] - 2.0).abs() < 1e-6);
    }

    /// Finite-difference check of the hand-written MLP vjp — the anchor the
    /// whole gradient-method test suite leans on.
    #[test]
    fn mlp_vjp_matches_finite_differences() {
        let mut rng = Rng::new(11);
        let dyn_ = MlpDynamics::new(4, 6, &mut rng);
        let z: Vec<f32> = (0..4).map(|i| 0.3 * (i as f32) - 0.4).collect();
        let a: Vec<f32> = (0..4).map(|i| 1.0 - 0.2 * i as f32).collect();
        let (az, atheta) = dyn_.f_vjp(0.0, &z, &a);

        let eps = 1e-3f32;
        // d/dz check
        for j in 0..z.len() {
            let mut zp = z.clone();
            zp[j] += eps;
            let mut zm = z.clone();
            zm[j] -= eps;
            let fp = dyn_.f(0.0, &zp);
            let fm = dyn_.f(0.0, &zm);
            let fd: f32 = fp
                .iter()
                .zip(&fm)
                .zip(&a)
                .map(|((p, m), ai)| (p - m) / (2.0 * eps) * ai)
                .sum();
            assert!(
                (fd - az[j]).abs() < 2e-3,
                "z[{j}]: fd {fd} vs vjp {}",
                az[j]
            );
        }
        // d/dθ spot check on a handful of random coordinates
        let mut dyn_mut = dyn_;
        let theta0 = dyn_mut.params().to_vec();
        for &k in &[0usize, 5, 17, theta0.len() - 1] {
            let mut tp = theta0.clone();
            tp[k] += eps;
            dyn_mut.set_params(&tp);
            let fp = dyn_mut.f(0.0, &z);
            let mut tm = theta0.clone();
            tm[k] -= eps;
            dyn_mut.set_params(&tm);
            let fm = dyn_mut.f(0.0, &z);
            dyn_mut.set_params(&theta0);
            let fd: f32 = fp
                .iter()
                .zip(&fm)
                .zip(&a)
                .map(|((p, m), ai)| (p - m) / (2.0 * eps) * ai)
                .sum();
            assert!(
                (fd - atheta[k]).abs() < 2e-3,
                "θ[{k}]: fd {fd} vs vjp {}",
                atheta[k]
            );
        }
    }

    #[test]
    fn complex_eigen_blocks_rotate() {
        let d = ComplexEigenDynamics::new(vec![(0.0, 1.0)]);
        // eigenvalues ±i → pure rotation: f([1,0]) = [0,1]
        let out = d.f(0.0, &[1.0, 0.0]);
        assert_eq!(out, vec![0.0, 1.0]);
    }

    #[test]
    fn counters_accumulate() {
        let toy = LinearToy::new(1.0, 1);
        toy.f(0.0, &[1.0]);
        toy.f(0.0, &[1.0]);
        toy.f_vjp(0.0, &[1.0], &[1.0]);
        assert_eq!(toy.counters().f_evals.get(), 2);
        assert_eq!(toy.counters().vjp_evals.get(), 1);
        toy.counters().reset();
        assert_eq!(toy.counters().f_evals.get(), 0);
    }
}
