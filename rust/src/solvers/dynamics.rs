//! The [`Dynamics`] abstraction: the ODE right-hand side `f(t, z; θ)` and
//! its vector-Jacobian products.
//!
//! Two families implement it:
//! * native implementations in this file ([`LinearToy`], [`MlpDynamics`],
//!   [`ComplexEigenDynamics`]) — closed-form or small hand-differentiated
//!   models used by the toy experiment (paper Fig. 4) and by the
//!   property-test suite;
//! * `runtime::HloDynamics` — batched model graphs AOT-compiled from JAX
//!   (L2) containing the Pallas kernels (L1), used by every real experiment.
//!
//! Gradient methods compose everything they need (ψ, ψ⁻¹, ψ-vjp, the
//! adjoint's augmented dynamics) from `f` and `f_vjp`, so a single trait
//! covers all four estimation protocols.  Fused per-step executables (the
//! Pallas `alf_step` path) are an optional fast path — see
//! [`Dynamics::fused_alf`].

use super::batch::BatchSpec;
use std::sync::atomic::{AtomicU64, Ordering};

/// Relaxed atomic event counter.  Atomic (rather than `Cell`) so one
/// dynamics can be shared by `util::pool` workers when the batch driver
/// shards a mini-batch across threads — counts stay exact under
/// concurrent increments.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Overwrite the value.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed)
    }

    /// Increment by `n` (one atomic op, safe across threads).
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
}

impl Clone for Counter {
    fn clone(&self) -> Self {
        Counter(AtomicU64::new(self.get()))
    }
}

/// Evaluation counters, used by the Table-1 complexity validation and the
/// computation-cost columns of the benches.
///
/// For **host** dynamics the counts are in *per-sample* units: a batched
/// evaluation over `B` rows counts `B` evaluations, so the accounting is
/// invariant to how a native batch is sharded or vectorized.  For
/// **device-batched** dynamics (`HloDynamics`) one count is one device
/// execute — the compiled graph already spans the whole batch, matching
/// how the paper costs a batched model evaluation.
#[derive(Debug, Default, Clone)]
pub struct EvalCounters {
    /// Number of `f(t, z)` evaluations since the last reset.
    pub f_evals: Counter,
    /// Number of `f_vjp` evaluations since the last reset.
    pub vjp_evals: Counter,
}

impl EvalCounters {
    /// Zero both counters (called at the start of each gradient pass).
    pub fn reset(&self) {
        self.f_evals.set(0);
        self.vjp_evals.set(0);
    }
}

/// ODE right-hand side with parameters.
pub trait Dynamics {
    /// Flattened state dimension (batch × features for batched models).
    fn dim(&self) -> usize;

    /// Flattened parameter dimension of θ_f.
    fn param_dim(&self) -> usize;

    /// Evaluate `dz/dt = f(t, z; θ)`.
    fn f(&self, t: f64, z: &[f32]) -> Vec<f32>;

    /// Vector-Jacobian products: given cotangent `a`, return
    /// `(aᵀ ∂f/∂z, aᵀ ∂f/∂θ)`.
    fn f_vjp(&self, t: f64, z: &[f32], a: &[f32]) -> (Vec<f32>, Vec<f32>);

    /// The flat parameter vector θ_f.
    fn params(&self) -> &[f32];

    /// Replace θ_f (length must match [`Dynamics::param_dim`]).
    fn set_params(&mut self, theta: &[f32]);

    /// Evaluation counters used by the Table-1 cost accounting.
    fn counters(&self) -> &EvalCounters;

    /// Number of "layers" N_f for Table-1 style accounting (1 for toy).
    fn depth_nf(&self) -> usize {
        1
    }

    /// `true` when `f` is itself a device-compiled graph over a *fixed*
    /// `[B, n_z]` layout (`runtime::HloDynamics`): the batch dimension is
    /// baked into the executable, so the batch driver must keep one fused
    /// device call per evaluation instead of sharding rows on the host.
    fn is_device_batched(&self) -> bool {
        false
    }

    /// Batched RHS over a row-major `[B, n_z]` buffer with per-row times
    /// (`ts[b]` is row `b`'s evaluation time — rows desynchronize under
    /// per-sample adaptive stepping).
    ///
    /// Default: single-sample fallback looping rows through
    /// [`Dynamics::f`], so existing dynamics keep working unchanged;
    /// vectorizable models override it (e.g. [`LinearToy`]) and count
    /// `spec.batch` evaluations per call.
    fn f_batch(&self, ts: &[f64], z: &[f32], spec: &BatchSpec) -> Vec<f32> {
        debug_assert_eq!(ts.len(), spec.batch);
        debug_assert_eq!(z.len(), spec.flat_len());
        let mut out = Vec::with_capacity(z.len());
        for (b, &t) in ts.iter().enumerate() {
            out.extend_from_slice(&self.f(t, spec.row(z, b)));
        }
        out
    }

    /// Batched vector-Jacobian products with the θ-cotangent **summed over
    /// rows** — the mini-batch gradient the training methods accumulate.
    /// Default: single-sample fallback looping rows through
    /// [`Dynamics::f_vjp`].
    fn f_vjp_batch(
        &self,
        ts: &[f64],
        z: &[f32],
        a: &[f32],
        spec: &BatchSpec,
    ) -> (Vec<f32>, Vec<f32>) {
        debug_assert_eq!(ts.len(), spec.batch);
        let mut az = Vec::with_capacity(z.len());
        let mut ath = vec![0.0f32; self.param_dim()];
        for (b, &t) in ts.iter().enumerate() {
            let (az_b, ath_b) = self.f_vjp(t, spec.row(z, b), spec.row(a, b));
            az.extend_from_slice(&az_b);
            crate::tensor::axpy(1.0, &ath_b, &mut ath);
        }
        (az, ath)
    }

    /// Batched vjp keeping the θ-cotangent **per row** (`[B, P]`) — the
    /// adjoint method integrates a separate `g_θ` block per sample, so it
    /// cannot use the summed variant.  Default loops rows through
    /// [`Dynamics::f_vjp`].
    fn f_vjp_batch_rows(
        &self,
        ts: &[f64],
        z: &[f32],
        a: &[f32],
        spec: &BatchSpec,
    ) -> (Vec<f32>, Vec<f32>) {
        debug_assert_eq!(ts.len(), spec.batch);
        let mut az = Vec::with_capacity(z.len());
        let mut ath = Vec::with_capacity(spec.batch * self.param_dim());
        for (b, &t) in ts.iter().enumerate() {
            let (az_b, ath_b) = self.f_vjp(t, spec.row(z, b), spec.row(a, b));
            az.extend_from_slice(&az_b);
            ath.extend_from_slice(&ath_b);
        }
        (az, ath)
    }

    // ---- workspace (allocation-free) entry points ----------------------
    //
    // The `_into` variants write into caller-provided buffers so the
    // solver/grad hot loops can run without touching the allocator.  The
    // defaults forward to the allocating methods (every existing dynamics
    // keeps working, value-identical); native dynamics with closed-form
    // arithmetic ([`LinearToy`]) override them allocation-free.

    /// Evaluate `f` into a caller-provided buffer (`out.len() == z.len()`,
    /// which must not alias `z`).  Default forwards to [`Dynamics::f`].
    fn f_into(&self, t: f64, z: &[f32], out: &mut [f32]) {
        out.copy_from_slice(&self.f(t, z));
    }

    /// Vjp into caller buffers: `az_out` receives `aᵀ ∂f/∂z`; the
    /// θ-cotangent is **accumulated** into `ath_acc` (`+=`, bit-identical
    /// to the `axpy(1.0, ..)` the gradient loops previously performed).
    /// Default forwards to [`Dynamics::f_vjp`].
    fn f_vjp_into(&self, t: f64, z: &[f32], a: &[f32], az_out: &mut [f32], ath_acc: &mut [f32]) {
        let (az, ath) = self.f_vjp(t, z, a);
        az_out.copy_from_slice(&az);
        crate::tensor::axpy(1.0, &ath, ath_acc);
    }

    /// Batched [`Dynamics::f_into`] over a `[B, n_z]` buffer.  Default
    /// forwards to [`Dynamics::f_batch`].
    fn f_batch_into(&self, ts: &[f64], z: &[f32], spec: &BatchSpec, out: &mut [f32]) {
        out.copy_from_slice(&self.f_batch(ts, z, spec));
    }

    /// Batched [`Dynamics::f_vjp_into`] with the θ-cotangent summed over
    /// rows and accumulated into `ath_acc`.  Default forwards to
    /// [`Dynamics::f_vjp_batch`].
    fn f_vjp_batch_into(
        &self,
        ts: &[f64],
        z: &[f32],
        a: &[f32],
        spec: &BatchSpec,
        az_out: &mut [f32],
        ath_acc: &mut [f32],
    ) {
        let (az, ath) = self.f_vjp_batch(ts, z, a, spec);
        az_out.copy_from_slice(&az);
        crate::tensor::axpy(1.0, &ath, ath_acc);
    }

    /// Optional fused damped-ALF step ψ executed device-side in one call
    /// (the L1 Pallas kernel path).  Returns `(z_out, v_out, err_embedded)`.
    /// Default: `None`, and the solver composes the step from [`Dynamics::f`].
    fn fused_alf(
        &self,
        _z: &[f32],
        _v: &[f32],
        _t: f64,
        _h: f64,
        _eta: f64,
    ) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        None
    }

    /// Optional fused ψ⁻¹ (see [`Dynamics::fused_alf`]); returns `(z_in, v_in)`.
    fn fused_alf_inv(
        &self,
        _z: &[f32],
        _v: &[f32],
        _t_out: f64,
        _h: f64,
        _eta: f64,
    ) -> Option<(Vec<f32>, Vec<f32>)> {
        None
    }

    /// Optional fused ψ-vjp; returns `(a_z, a_v, a_θ)` for cotangents on the
    /// step outputs.
    #[allow(clippy::too_many_arguments)]
    fn fused_alf_vjp(
        &self,
        _z: &[f32],
        _v: &[f32],
        _t: f64,
        _h: f64,
        _eta: f64,
        _az_out: &[f32],
        _av_out: &[f32],
    ) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        None
    }

    /// Optional fused MALI backward micro-step: ψ⁻¹ reconstruction *and*
    /// the vjp through ψ at the reconstructed point, in one device call —
    /// halves the backward pass's PJRT round-trips.  Inputs are the step
    /// *outputs* `(z_out, v_out)` at `t_out` and the output cotangents;
    /// returns `(z_in, v_in, a_z, a_v, a_θ)`.
    #[allow(clippy::too_many_arguments)]
    fn fused_alf_bwd(
        &self,
        _z_out: &[f32],
        _v_out: &[f32],
        _t_out: f64,
        _h: f64,
        _eta: f64,
        _az_out: &[f32],
        _av_out: &[f32],
    ) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        None
    }

    // ---- allocation-free fused entry points ----------------------------
    //
    // The `_into` forms of the four fused hooks above: they write into
    // caller buffers and return `true` when the dynamics took the fused
    // path, `false` to let the solver compose the step from `f`/`f_vjp`.
    // Defaults wrap the allocating `Option` hooks so a dynamics that only
    // implements those (e.g. `runtime::HloDynamics`) still fuses on the
    // workspace path; native backends override both forms in place.

    /// Fused ψ into caller buffers.  Returns `true` if the fused path ran.
    #[allow(clippy::too_many_arguments)]
    fn fused_alf_into(
        &self,
        z: &[f32],
        v: &[f32],
        t: f64,
        h: f64,
        eta: f64,
        z_out: &mut [f32],
        v_out: &mut [f32],
        err_out: &mut [f32],
    ) -> bool {
        if let Some((zf, vf, ef)) = self.fused_alf(z, v, t, h, eta) {
            z_out.copy_from_slice(&zf);
            v_out.copy_from_slice(&vf);
            err_out.copy_from_slice(&ef);
            true
        } else {
            false
        }
    }

    /// Fused ψ⁻¹ into caller buffers.  Returns `true` if the fused path ran.
    #[allow(clippy::too_many_arguments)]
    fn fused_alf_inv_into(
        &self,
        z_out: &[f32],
        v_out: &[f32],
        t_out: f64,
        h: f64,
        eta: f64,
        z_in: &mut [f32],
        v_in: &mut [f32],
    ) -> bool {
        if let Some((zf, vf)) = self.fused_alf_inv(z_out, v_out, t_out, h, eta) {
            z_in.copy_from_slice(&zf);
            v_in.copy_from_slice(&vf);
            true
        } else {
            false
        }
    }

    /// Fused ψ-vjp into caller buffers; the θ-cotangent is **accumulated**
    /// into `ath_acc` (`+=`).  Returns `true` if the fused path ran.
    #[allow(clippy::too_many_arguments)]
    fn fused_alf_vjp_into(
        &self,
        z: &[f32],
        v: &[f32],
        t: f64,
        h: f64,
        eta: f64,
        az_out: &[f32],
        av_out: &[f32],
        az_in: &mut [f32],
        av_in: &mut [f32],
        ath_acc: &mut [f32],
    ) -> bool {
        if let Some((az, av, ath)) = self.fused_alf_vjp(z, v, t, h, eta, az_out, av_out) {
            az_in.copy_from_slice(&az);
            av_in.copy_from_slice(&av);
            crate::tensor::axpy(1.0, &ath, ath_acc);
            true
        } else {
            false
        }
    }

    /// Fused backward micro-step (ψ⁻¹ + ψ-vjp) into caller buffers; the
    /// θ-cotangent is accumulated into `ath_acc`.  Returns `true` if the
    /// fused path ran.
    #[allow(clippy::too_many_arguments)]
    fn fused_alf_bwd_into(
        &self,
        z_out: &[f32],
        v_out: &[f32],
        t_out: f64,
        h: f64,
        eta: f64,
        az_out: &[f32],
        av_out: &[f32],
        z_in: &mut [f32],
        v_in: &mut [f32],
        az_in: &mut [f32],
        av_in: &mut [f32],
        ath_acc: &mut [f32],
    ) -> bool {
        if let Some((zf, vf, az, av, ath)) =
            self.fused_alf_bwd(z_out, v_out, t_out, h, eta, az_out, av_out)
        {
            z_in.copy_from_slice(&zf);
            v_in.copy_from_slice(&vf);
            az_in.copy_from_slice(&az);
            av_in.copy_from_slice(&av);
            crate::tensor::axpy(1.0, &ath, ath_acc);
            true
        } else {
            false
        }
    }

    // ---- batched fused entry points ------------------------------------
    //
    // Per-row `(t, h)` fused steps over the flat `[B, n_z]` buffer.  A
    // backend whose layer stack rides `matmul_into` fuses the whole batch
    // in one pass; defaults return `false` so the solver falls back to its
    // composed batched arithmetic.

    /// Batched fused ψ with per-row `(t, h)`.  Returns `true` if fused.
    #[allow(clippy::too_many_arguments)]
    fn fused_alf_batch_into(
        &self,
        _ts: &[f64],
        _hs: &[f64],
        _z: &[f32],
        _v: &[f32],
        _eta: f64,
        _spec: &BatchSpec,
        _z_out: &mut [f32],
        _v_out: &mut [f32],
        _err_out: &mut [f32],
    ) -> bool {
        false
    }

    /// Batched fused ψ⁻¹ with per-row `(t_out, h)`.  Returns `true` if fused.
    #[allow(clippy::too_many_arguments)]
    fn fused_alf_inv_batch_into(
        &self,
        _ts_out: &[f64],
        _hs: &[f64],
        _z_out: &[f32],
        _v_out: &[f32],
        _eta: f64,
        _spec: &BatchSpec,
        _z_in: &mut [f32],
        _v_in: &mut [f32],
    ) -> bool {
        false
    }

    /// Batched fused ψ-vjp; the row-summed θ-cotangent is accumulated into
    /// `ath_acc`.  Returns `true` if fused.
    #[allow(clippy::too_many_arguments)]
    fn fused_alf_vjp_batch_into(
        &self,
        _ts: &[f64],
        _hs: &[f64],
        _z: &[f32],
        _v: &[f32],
        _eta: f64,
        _spec: &BatchSpec,
        _az_out: &[f32],
        _av_out: &[f32],
        _az_in: &mut [f32],
        _av_in: &mut [f32],
        _ath_acc: &mut [f32],
    ) -> bool {
        false
    }

    /// Clone this dynamics into a fresh boxed instance with **zeroed
    /// counters** — the copy-on-write hook behind
    /// `serve::ModelRegistry::hot_swap`.  Returns `None` (the default)
    /// when the model cannot be duplicated host-side (e.g. a
    /// device-compiled `HloDynamics` whose executable is not cloneable),
    /// in which case the registry refuses the swap instead of draining.
    fn clone_box(&self) -> Option<Box<dyn Dynamics + Send + Sync>> {
        None
    }
}

// ---------------------------------------------------------------------------
// Scoped counter view
// ---------------------------------------------------------------------------

/// A forwarding view over a shared dynamics with its **own** evaluation
/// counters.
///
/// The serve worker and the pooled gradient drivers used to cost a batch
/// by the delta of the *shared* registry counters around the call — exact
/// for a single writer, silently interleaved the moment two workers (or a
/// fine-tune loop and an inference session) drive the same model
/// concurrently.  Wrapping the shared `&dyn Dynamics` in a
/// `ScopedDynamics` gives each pass a private window: every forwarded
/// call still increments the inner (global) counters — registry-wide
/// totals and shutdown accounting are unchanged — while the scope mirrors
/// the same per-sample units locally, so `scoped.counters()` reads an
/// exact, interleaving-free count for this pass alone.
///
/// Mirroring is by the documented counting convention (per-sample units
/// for host dynamics, one unit per device execute), *not* by inner-counter
/// deltas — deltas would re-introduce exactly the race this type removes.
pub struct ScopedDynamics<'a> {
    inner: &'a (dyn Dynamics + Sync),
    scope: EvalCounters,
}

impl<'a> ScopedDynamics<'a> {
    /// Wrap a shared dynamics; the scope counters start at zero.
    pub fn new(inner: &'a (dyn Dynamics + Sync)) -> Self {
        ScopedDynamics {
            inner,
            scope: EvalCounters::default(),
        }
    }

    /// Counting unit for a batched call: `B` per-sample units for host
    /// dynamics, one per execute for device-batched graphs (matching the
    /// [`EvalCounters`] convention).
    fn batch_units(&self, spec: &BatchSpec) -> u64 {
        if self.inner.is_device_batched() {
            1
        } else {
            spec.batch as u64
        }
    }
}

impl std::fmt::Debug for ScopedDynamics<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ScopedDynamics")
            .field("scope", &self.scope)
            .finish_non_exhaustive()
    }
}

impl Dynamics for ScopedDynamics<'_> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn param_dim(&self) -> usize {
        self.inner.param_dim()
    }

    fn f(&self, t: f64, z: &[f32]) -> Vec<f32> {
        self.scope.f_evals.add(1);
        self.inner.f(t, z)
    }

    fn f_vjp(&self, t: f64, z: &[f32], a: &[f32]) -> (Vec<f32>, Vec<f32>) {
        self.scope.vjp_evals.add(1);
        self.inner.f_vjp(t, z, a)
    }

    fn params(&self) -> &[f32] {
        self.inner.params()
    }

    fn set_params(&mut self, _theta: &[f32]) {
        // The scope borrows the model shared; parameter updates go through
        // `ModelRegistry::hot_swap` on a fresh clone, never through a view.
        panic!("ScopedDynamics is a read-only view; set_params is not supported");
    }

    fn counters(&self) -> &EvalCounters {
        &self.scope
    }

    fn depth_nf(&self) -> usize {
        self.inner.depth_nf()
    }

    fn is_device_batched(&self) -> bool {
        self.inner.is_device_batched()
    }

    fn f_batch(&self, ts: &[f64], z: &[f32], spec: &BatchSpec) -> Vec<f32> {
        self.scope.f_evals.add(self.batch_units(spec));
        self.inner.f_batch(ts, z, spec)
    }

    fn f_vjp_batch(
        &self,
        ts: &[f64],
        z: &[f32],
        a: &[f32],
        spec: &BatchSpec,
    ) -> (Vec<f32>, Vec<f32>) {
        self.scope.vjp_evals.add(self.batch_units(spec));
        self.inner.f_vjp_batch(ts, z, a, spec)
    }

    fn f_vjp_batch_rows(
        &self,
        ts: &[f64],
        z: &[f32],
        a: &[f32],
        spec: &BatchSpec,
    ) -> (Vec<f32>, Vec<f32>) {
        self.scope.vjp_evals.add(self.batch_units(spec));
        self.inner.f_vjp_batch_rows(ts, z, a, spec)
    }

    fn f_into(&self, t: f64, z: &[f32], out: &mut [f32]) {
        self.scope.f_evals.add(1);
        self.inner.f_into(t, z, out);
    }

    fn f_vjp_into(&self, t: f64, z: &[f32], a: &[f32], az_out: &mut [f32], ath_acc: &mut [f32]) {
        self.scope.vjp_evals.add(1);
        self.inner.f_vjp_into(t, z, a, az_out, ath_acc);
    }

    fn f_batch_into(&self, ts: &[f64], z: &[f32], spec: &BatchSpec, out: &mut [f32]) {
        self.scope.f_evals.add(self.batch_units(spec));
        self.inner.f_batch_into(ts, z, spec, out);
    }

    fn f_vjp_batch_into(
        &self,
        ts: &[f64],
        z: &[f32],
        a: &[f32],
        spec: &BatchSpec,
        az_out: &mut [f32],
        ath_acc: &mut [f32],
    ) {
        self.scope.vjp_evals.add(self.batch_units(spec));
        self.inner.f_vjp_batch_into(ts, z, a, spec, az_out, ath_acc);
    }

    fn fused_alf(
        &self,
        z: &[f32],
        v: &[f32],
        t: f64,
        h: f64,
        eta: f64,
    ) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let out = self.inner.fused_alf(z, v, t, h, eta);
        if out.is_some() {
            self.scope.f_evals.add(1);
        }
        out
    }

    fn fused_alf_inv(
        &self,
        z: &[f32],
        v: &[f32],
        t_out: f64,
        h: f64,
        eta: f64,
    ) -> Option<(Vec<f32>, Vec<f32>)> {
        let out = self.inner.fused_alf_inv(z, v, t_out, h, eta);
        if out.is_some() {
            self.scope.f_evals.add(1);
        }
        out
    }

    fn fused_alf_vjp(
        &self,
        z: &[f32],
        v: &[f32],
        t: f64,
        h: f64,
        eta: f64,
        az_out: &[f32],
        av_out: &[f32],
    ) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let out = self.inner.fused_alf_vjp(z, v, t, h, eta, az_out, av_out);
        if out.is_some() {
            self.scope.vjp_evals.add(1);
        }
        out
    }

    fn fused_alf_bwd(
        &self,
        z_out: &[f32],
        v_out: &[f32],
        t_out: f64,
        h: f64,
        eta: f64,
        az_out: &[f32],
        av_out: &[f32],
    ) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let out = self
            .inner
            .fused_alf_bwd(z_out, v_out, t_out, h, eta, az_out, av_out);
        if out.is_some() {
            self.scope.f_evals.add(1);
            self.scope.vjp_evals.add(1);
        }
        out
    }

    fn fused_alf_into(
        &self,
        z: &[f32],
        v: &[f32],
        t: f64,
        h: f64,
        eta: f64,
        z_out: &mut [f32],
        v_out: &mut [f32],
        err_out: &mut [f32],
    ) -> bool {
        let ran = self.inner.fused_alf_into(z, v, t, h, eta, z_out, v_out, err_out);
        if ran {
            self.scope.f_evals.add(1);
        }
        ran
    }

    fn fused_alf_inv_into(
        &self,
        z_out: &[f32],
        v_out: &[f32],
        t_out: f64,
        h: f64,
        eta: f64,
        z_in: &mut [f32],
        v_in: &mut [f32],
    ) -> bool {
        let ran = self
            .inner
            .fused_alf_inv_into(z_out, v_out, t_out, h, eta, z_in, v_in);
        if ran {
            self.scope.f_evals.add(1);
        }
        ran
    }

    fn fused_alf_vjp_into(
        &self,
        z: &[f32],
        v: &[f32],
        t: f64,
        h: f64,
        eta: f64,
        az_out: &[f32],
        av_out: &[f32],
        az_in: &mut [f32],
        av_in: &mut [f32],
        ath_acc: &mut [f32],
    ) -> bool {
        let ran = self
            .inner
            .fused_alf_vjp_into(z, v, t, h, eta, az_out, av_out, az_in, av_in, ath_acc);
        if ran {
            self.scope.vjp_evals.add(1);
        }
        ran
    }

    fn fused_alf_bwd_into(
        &self,
        z_out: &[f32],
        v_out: &[f32],
        t_out: f64,
        h: f64,
        eta: f64,
        az_out: &[f32],
        av_out: &[f32],
        z_in: &mut [f32],
        v_in: &mut [f32],
        az_in: &mut [f32],
        av_in: &mut [f32],
        ath_acc: &mut [f32],
    ) -> bool {
        let ran = self.inner.fused_alf_bwd_into(
            z_out, v_out, t_out, h, eta, az_out, av_out, z_in, v_in, az_in, av_in, ath_acc,
        );
        if ran {
            self.scope.f_evals.add(1);
            self.scope.vjp_evals.add(1);
        }
        ran
    }

    fn fused_alf_batch_into(
        &self,
        ts: &[f64],
        hs: &[f64],
        z: &[f32],
        v: &[f32],
        eta: f64,
        spec: &BatchSpec,
        z_out: &mut [f32],
        v_out: &mut [f32],
        err_out: &mut [f32],
    ) -> bool {
        let ran = self
            .inner
            .fused_alf_batch_into(ts, hs, z, v, eta, spec, z_out, v_out, err_out);
        if ran {
            self.scope.f_evals.add(self.batch_units(spec));
        }
        ran
    }

    fn fused_alf_inv_batch_into(
        &self,
        ts_out: &[f64],
        hs: &[f64],
        z_out: &[f32],
        v_out: &[f32],
        eta: f64,
        spec: &BatchSpec,
        z_in: &mut [f32],
        v_in: &mut [f32],
    ) -> bool {
        let ran = self
            .inner
            .fused_alf_inv_batch_into(ts_out, hs, z_out, v_out, eta, spec, z_in, v_in);
        if ran {
            self.scope.f_evals.add(self.batch_units(spec));
        }
        ran
    }

    fn fused_alf_vjp_batch_into(
        &self,
        ts: &[f64],
        hs: &[f64],
        z: &[f32],
        v: &[f32],
        eta: f64,
        spec: &BatchSpec,
        az_out: &[f32],
        av_out: &[f32],
        az_in: &mut [f32],
        av_in: &mut [f32],
        ath_acc: &mut [f32],
    ) -> bool {
        let ran = self.inner.fused_alf_vjp_batch_into(
            ts, hs, z, v, eta, spec, az_out, av_out, az_in, av_in, ath_acc,
        );
        if ran {
            self.scope.vjp_evals.add(self.batch_units(spec));
        }
        ran
    }
}

// ---------------------------------------------------------------------------
// Native dynamics #1: the paper's toy problem  dz/dt = α z  (Eq. 6).
// ---------------------------------------------------------------------------

/// `dz/dt = α z` with θ = [α].  Every quantity in paper Eq. (7) has a closed
/// form, so this is the reference for gradient-error measurements (Fig. 4).
#[derive(Debug)]
pub struct LinearToy {
    pub alpha: Vec<f32>, // length-1 param vector
    pub n: usize,
    counters: EvalCounters,
}

impl LinearToy {
    pub fn new(alpha: f64, n: usize) -> Self {
        LinearToy {
            alpha: vec![alpha as f32],
            n,
            counters: EvalCounters::default(),
        }
    }

    pub fn analytic_z(&self, z0: &[f32], t: f64) -> Vec<f32> {
        let a = self.alpha[0] as f64;
        z0.iter().map(|&z| (z as f64 * (a * t).exp()) as f32).collect()
    }

    /// Analytic `dL/dz0` and `dL/dα` for `L = z(T)²` (summed over
    /// components), per paper Eq. (7).
    pub fn analytic_grads(&self, z0: &[f32], t_end: f64) -> (Vec<f32>, f64) {
        let a = self.alpha[0] as f64;
        let e = (2.0 * a * t_end).exp();
        let dz0: Vec<f32> = z0.iter().map(|&z| (2.0 * z as f64 * e) as f32).collect();
        let dalpha: f64 = z0
            .iter()
            .map(|&z| 2.0 * t_end * (z as f64) * (z as f64) * e)
            .sum();
        (dz0, dalpha)
    }
}

impl Dynamics for LinearToy {
    fn dim(&self) -> usize {
        self.n
    }

    fn param_dim(&self) -> usize {
        1
    }

    fn f(&self, _t: f64, z: &[f32]) -> Vec<f32> {
        self.counters.f_evals.add(1);
        let a = self.alpha[0];
        z.iter().map(|&zi| a * zi).collect()
    }

    fn f_vjp(&self, _t: f64, z: &[f32], a: &[f32]) -> (Vec<f32>, Vec<f32>) {
        self.counters.vjp_evals.add(1);
        let alpha = self.alpha[0];
        let az: Vec<f32> = a.iter().map(|&ai| alpha * ai).collect();
        let datheta: f64 = a
            .iter()
            .zip(z)
            .map(|(&ai, &zi)| ai as f64 * zi as f64)
            .sum();
        (az, vec![datheta as f32])
    }

    // `dz/dt = αz` is elementwise, so the batched entry points vectorize
    // over the whole flat `[B·n]` buffer in one pass (row arithmetic stays
    // bit-identical to the per-row fallback).

    fn f_batch(&self, ts: &[f64], z: &[f32], spec: &BatchSpec) -> Vec<f32> {
        debug_assert_eq!(ts.len(), spec.batch);
        debug_assert_eq!(z.len(), spec.flat_len());
        self.counters.f_evals.add(spec.batch as u64);
        let a = self.alpha[0];
        z.iter().map(|&zi| a * zi).collect()
    }

    fn f_vjp_batch(
        &self,
        ts: &[f64],
        z: &[f32],
        a: &[f32],
        spec: &BatchSpec,
    ) -> (Vec<f32>, Vec<f32>) {
        debug_assert_eq!(ts.len(), spec.batch);
        self.counters.vjp_evals.add(spec.batch as u64);
        let alpha = self.alpha[0];
        let az: Vec<f32> = a.iter().map(|&ai| alpha * ai).collect();
        // per-row f64 reduction then f32 row-order sum — the exact FP
        // sequence of the fallback path (roundoff equivalence tests)
        let mut dtheta = 0.0f32;
        for b in 0..spec.batch {
            let row_sum: f64 = spec
                .row(a, b)
                .iter()
                .zip(spec.row(z, b))
                .map(|(&ai, &zi)| ai as f64 * zi as f64)
                .sum();
            dtheta += row_sum as f32;
        }
        (az, vec![dtheta])
    }

    fn f_vjp_batch_rows(
        &self,
        ts: &[f64],
        z: &[f32],
        a: &[f32],
        spec: &BatchSpec,
    ) -> (Vec<f32>, Vec<f32>) {
        debug_assert_eq!(ts.len(), spec.batch);
        self.counters.vjp_evals.add(spec.batch as u64);
        let alpha = self.alpha[0];
        let az: Vec<f32> = a.iter().map(|&ai| alpha * ai).collect();
        let mut ath = Vec::with_capacity(spec.batch);
        for b in 0..spec.batch {
            let row_sum: f64 = spec
                .row(a, b)
                .iter()
                .zip(spec.row(z, b))
                .map(|(&ai, &zi)| ai as f64 * zi as f64)
                .sum();
            ath.push(row_sum as f32);
        }
        (az, ath)
    }

    // Allocation-free workspace entry points: the bench/alloc-test hot
    // paths run on this dynamics, so every `_into` writes in place with
    // the exact arithmetic (and counter accounting) of the allocating
    // methods above — bit-identical results, zero heap traffic.

    fn f_into(&self, _t: f64, z: &[f32], out: &mut [f32]) {
        self.counters.f_evals.add(1);
        let a = self.alpha[0];
        for (o, &zi) in out.iter_mut().zip(z) {
            *o = a * zi;
        }
    }

    fn f_vjp_into(&self, _t: f64, z: &[f32], a: &[f32], az_out: &mut [f32], ath_acc: &mut [f32]) {
        self.counters.vjp_evals.add(1);
        let alpha = self.alpha[0];
        for (o, &ai) in az_out.iter_mut().zip(a) {
            *o = alpha * ai;
        }
        let datheta: f64 = a
            .iter()
            .zip(z)
            .map(|(&ai, &zi)| ai as f64 * zi as f64)
            .sum();
        ath_acc[0] += datheta as f32;
    }

    fn f_batch_into(&self, ts: &[f64], z: &[f32], spec: &BatchSpec, out: &mut [f32]) {
        debug_assert_eq!(ts.len(), spec.batch);
        debug_assert_eq!(z.len(), spec.flat_len());
        self.counters.f_evals.add(spec.batch as u64);
        let a = self.alpha[0];
        for (o, &zi) in out.iter_mut().zip(z) {
            *o = a * zi;
        }
    }

    fn f_vjp_batch_into(
        &self,
        ts: &[f64],
        z: &[f32],
        a: &[f32],
        spec: &BatchSpec,
        az_out: &mut [f32],
        ath_acc: &mut [f32],
    ) {
        debug_assert_eq!(ts.len(), spec.batch);
        self.counters.vjp_evals.add(spec.batch as u64);
        let alpha = self.alpha[0];
        for (o, &ai) in az_out.iter_mut().zip(a) {
            *o = alpha * ai;
        }
        // same FP sequence as `f_vjp_batch`: per-row f64 reduction, f32
        // row-order sum into a local, one accumulate at the end
        let mut dtheta = 0.0f32;
        for b in 0..spec.batch {
            let row_sum: f64 = spec
                .row(a, b)
                .iter()
                .zip(spec.row(z, b))
                .map(|(&ai, &zi)| ai as f64 * zi as f64)
                .sum();
            dtheta += row_sum as f32;
        }
        ath_acc[0] += dtheta;
    }

    fn params(&self) -> &[f32] {
        &self.alpha
    }

    fn set_params(&mut self, theta: &[f32]) {
        self.alpha.copy_from_slice(theta);
    }

    fn counters(&self) -> &EvalCounters {
        &self.counters
    }

    fn clone_box(&self) -> Option<Box<dyn Dynamics + Send + Sync>> {
        Some(Box::new(LinearToy {
            alpha: self.alpha.clone(),
            n: self.n,
            counters: EvalCounters::default(),
        }))
    }
}

// ---------------------------------------------------------------------------
// Native dynamics #2: small MLP  f(t, z) = W2 · tanh(W1 z + b1) + b2
// with hand-written vjp — the finite-difference anchor for every gradient
// method in the property-test suite.
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct MlpDynamics {
    pub d: usize,
    pub hidden: usize,
    /// θ layout: [W1 (h×d) | b1 (h) | W2 (d×h) | b2 (d)]
    theta: Vec<f32>,
    counters: EvalCounters,
}

impl MlpDynamics {
    pub fn new(d: usize, hidden: usize, rng: &mut crate::util::rng::Rng) -> Self {
        let n = hidden * d + hidden + d * hidden + d;
        let mut theta = vec![0.0f32; n];
        // modest init so trajectories stay tame over T ~ 1
        rng.fill_normal(&mut theta, 0.4 / (d.max(hidden) as f64).sqrt());
        MlpDynamics {
            d,
            hidden,
            theta,
            counters: EvalCounters::default(),
        }
    }

    fn split(&self) -> (&[f32], &[f32], &[f32], &[f32]) {
        let (d, h) = (self.d, self.hidden);
        let w1 = &self.theta[0..h * d];
        let b1 = &self.theta[h * d..h * d + h];
        let w2 = &self.theta[h * d + h..h * d + h + d * h];
        let b2 = &self.theta[h * d + h + d * h..];
        (w1, b1, w2, b2)
    }
}

impl Dynamics for MlpDynamics {
    fn dim(&self) -> usize {
        self.d
    }

    fn param_dim(&self) -> usize {
        self.theta.len()
    }

    fn f(&self, _t: f64, z: &[f32]) -> Vec<f32> {
        self.counters.f_evals.add(1);
        let (w1, b1, w2, b2) = self.split();
        let (d, h) = (self.d, self.hidden);
        let mut hid = vec![0.0f32; h];
        for i in 0..h {
            let mut acc = b1[i];
            for j in 0..d {
                acc += w1[i * d + j] * z[j];
            }
            hid[i] = acc.tanh();
        }
        let mut out = vec![0.0f32; d];
        for i in 0..d {
            let mut acc = b2[i];
            for j in 0..h {
                acc += w2[i * h + j] * hid[j];
            }
            out[i] = acc;
        }
        out
    }

    fn f_vjp(&self, _t: f64, z: &[f32], a: &[f32]) -> (Vec<f32>, Vec<f32>) {
        self.counters.vjp_evals.add(1);
        let (w1, b1, w2, _b2) = self.split();
        let (d, h) = (self.d, self.hidden);
        // forward intermediates
        let mut pre = vec![0.0f32; h];
        for i in 0..h {
            let mut acc = b1[i];
            for j in 0..d {
                acc += w1[i * d + j] * z[j];
            }
            pre[i] = acc;
        }
        let hid: Vec<f32> = pre.iter().map(|p| p.tanh()).collect();
        // backward
        // out_i = b2_i + Σ_j w2[i,j] hid_j  with cotangent a_i
        let mut d_hid = vec![0.0f32; h];
        let mut d_w2 = vec![0.0f32; d * h];
        let d_b2 = a.to_vec();
        for i in 0..d {
            for j in 0..h {
                d_w2[i * h + j] = a[i] * hid[j];
                d_hid[j] += a[i] * w2[i * h + j];
            }
        }
        // hid_j = tanh(pre_j)
        let d_pre: Vec<f32> = d_hid
            .iter()
            .zip(&hid)
            .map(|(&dh, &t)| dh * (1.0 - t * t))
            .collect();
        let mut d_w1 = vec![0.0f32; h * d];
        let d_b1 = d_pre.clone();
        let mut d_z = vec![0.0f32; d];
        for i in 0..h {
            for j in 0..d {
                d_w1[i * d + j] = d_pre[i] * z[j];
                d_z[j] += d_pre[i] * w1[i * d + j];
            }
        }
        let mut d_theta = Vec::with_capacity(self.theta.len());
        d_theta.extend_from_slice(&d_w1);
        d_theta.extend_from_slice(&d_b1);
        d_theta.extend_from_slice(&d_w2);
        d_theta.extend_from_slice(&d_b2);
        (d_z, d_theta)
    }

    fn params(&self) -> &[f32] {
        &self.theta
    }

    fn set_params(&mut self, theta: &[f32]) {
        self.theta.copy_from_slice(theta);
    }

    fn counters(&self) -> &EvalCounters {
        &self.counters
    }

    fn depth_nf(&self) -> usize {
        2
    }

    fn clone_box(&self) -> Option<Box<dyn Dynamics + Send + Sync>> {
        Some(Box::new(MlpDynamics {
            d: self.d,
            hidden: self.hidden,
            theta: self.theta.clone(),
            counters: EvalCounters::default(),
        }))
    }
}

// ---------------------------------------------------------------------------
// Native dynamics #3: stiff linear test  dz/dt = σ z  with complex-σ
// behaviour emulated by 2×2 rotation blocks — used by the stability tests.
// ---------------------------------------------------------------------------

/// Block-diagonal linear dynamics: each 2×2 block is `[[re, -im], [im, re]]`,
/// i.e. eigenvalues `re ± i·im` — lets tests place Jacobian eigenvalues
/// anywhere on the complex plane (Theorem 3.2).
#[derive(Debug)]
pub struct ComplexEigenDynamics {
    /// (re, im) per block; θ is empty (not trained).
    pub eigs: Vec<(f32, f32)>,
    counters: EvalCounters,
    empty: Vec<f32>,
}

impl ComplexEigenDynamics {
    pub fn new(eigs: Vec<(f32, f32)>) -> Self {
        ComplexEigenDynamics {
            eigs,
            counters: EvalCounters::default(),
            empty: Vec::new(),
        }
    }
}

impl Dynamics for ComplexEigenDynamics {
    fn dim(&self) -> usize {
        self.eigs.len() * 2
    }

    fn param_dim(&self) -> usize {
        0
    }

    fn f(&self, _t: f64, z: &[f32]) -> Vec<f32> {
        self.counters.f_evals.add(1);
        let mut out = vec![0.0f32; z.len()];
        for (b, &(re, im)) in self.eigs.iter().enumerate() {
            let (x, y) = (z[2 * b], z[2 * b + 1]);
            out[2 * b] = re * x - im * y;
            out[2 * b + 1] = im * x + re * y;
        }
        out
    }

    fn f_vjp(&self, _t: f64, z: &[f32], a: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let _ = z;
        self.counters.vjp_evals.add(1);
        // Jᵀ a for the block structure
        let mut az = vec![0.0f32; a.len()];
        for (b, &(re, im)) in self.eigs.iter().enumerate() {
            let (ax, ay) = (a[2 * b], a[2 * b + 1]);
            az[2 * b] = re * ax + im * ay;
            az[2 * b + 1] = -im * ax + re * ay;
        }
        (az, Vec::new())
    }

    fn params(&self) -> &[f32] {
        &self.empty
    }

    fn set_params(&mut self, _theta: &[f32]) {}

    fn counters(&self) -> &EvalCounters {
        &self.counters
    }

    fn clone_box(&self) -> Option<Box<dyn Dynamics + Send + Sync>> {
        Some(Box::new(ComplexEigenDynamics {
            eigs: self.eigs.clone(),
            counters: EvalCounters::default(),
            empty: Vec::new(),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn toy_matches_analytic_derivative() {
        let toy = LinearToy::new(0.5, 3);
        let z = [1.0f32, 2.0, -1.0];
        let fz = toy.f(0.0, &z);
        assert_eq!(fz, vec![0.5, 1.0, -0.5]);
        let (az, dth) = toy.f_vjp(0.0, &z, &[1.0, 1.0, 1.0]);
        assert_eq!(az, vec![0.5, 0.5, 0.5]);
        // dθ = Σ a_i z_i = 1 + 2 - 1 = 2
        assert!((dth[0] - 2.0).abs() < 1e-6);
    }

    /// Finite-difference check of the hand-written MLP vjp — the anchor the
    /// whole gradient-method test suite leans on.
    #[test]
    fn mlp_vjp_matches_finite_differences() {
        let mut rng = Rng::new(11);
        let dyn_ = MlpDynamics::new(4, 6, &mut rng);
        let z: Vec<f32> = (0..4).map(|i| 0.3 * (i as f32) - 0.4).collect();
        let a: Vec<f32> = (0..4).map(|i| 1.0 - 0.2 * i as f32).collect();
        let (az, atheta) = dyn_.f_vjp(0.0, &z, &a);

        let eps = 1e-3f32;
        // d/dz check
        for j in 0..z.len() {
            let mut zp = z.clone();
            zp[j] += eps;
            let mut zm = z.clone();
            zm[j] -= eps;
            let fp = dyn_.f(0.0, &zp);
            let fm = dyn_.f(0.0, &zm);
            let fd: f32 = fp
                .iter()
                .zip(&fm)
                .zip(&a)
                .map(|((p, m), ai)| (p - m) / (2.0 * eps) * ai)
                .sum();
            assert!(
                (fd - az[j]).abs() < 2e-3,
                "z[{j}]: fd {fd} vs vjp {}",
                az[j]
            );
        }
        // d/dθ spot check on a handful of random coordinates
        let mut dyn_mut = dyn_;
        let theta0 = dyn_mut.params().to_vec();
        for &k in &[0usize, 5, 17, theta0.len() - 1] {
            let mut tp = theta0.clone();
            tp[k] += eps;
            dyn_mut.set_params(&tp);
            let fp = dyn_mut.f(0.0, &z);
            let mut tm = theta0.clone();
            tm[k] -= eps;
            dyn_mut.set_params(&tm);
            let fm = dyn_mut.f(0.0, &z);
            dyn_mut.set_params(&theta0);
            let fd: f32 = fp
                .iter()
                .zip(&fm)
                .zip(&a)
                .map(|((p, m), ai)| (p - m) / (2.0 * eps) * ai)
                .sum();
            assert!(
                (fd - atheta[k]).abs() < 2e-3,
                "θ[{k}]: fd {fd} vs vjp {}",
                atheta[k]
            );
        }
    }

    #[test]
    fn complex_eigen_blocks_rotate() {
        let d = ComplexEigenDynamics::new(vec![(0.0, 1.0)]);
        // eigenvalues ±i → pure rotation: f([1,0]) = [0,1]
        let out = d.f(0.0, &[1.0, 0.0]);
        assert_eq!(out, vec![0.0, 1.0]);
    }

    /// The batched fallback must agree row-for-row with single-sample
    /// evaluation, and the summed-θ variant with the per-row variant.
    #[test]
    fn batched_fallback_matches_rows() {
        let mut rng = Rng::new(21);
        let dyn_ = MlpDynamics::new(3, 5, &mut rng);
        let spec = BatchSpec::new(4, 3);
        let mut z = vec![0.0f32; spec.flat_len()];
        rng.fill_uniform_sym(&mut z, 0.8);
        let ts = [0.0, 0.1, 0.2, 0.3];
        let fb = dyn_.f_batch(&ts, &z, &spec);
        for (b, &t) in ts.iter().enumerate() {
            assert_eq!(spec.row(&fb, b), dyn_.f(t, spec.row(&z, b)).as_slice());
        }
        let mut a = vec![0.0f32; spec.flat_len()];
        rng.fill_uniform_sym(&mut a, 1.0);
        let (az, ath) = dyn_.f_vjp_batch(&ts, &z, &a, &spec);
        let (az_rows, ath_rows) = dyn_.f_vjp_batch_rows(&ts, &z, &a, &spec);
        assert_eq!(az, az_rows);
        let p = dyn_.param_dim();
        assert_eq!(ath.len(), p);
        assert_eq!(ath_rows.len(), 4 * p);
        for (k, &summed) in ath.iter().enumerate() {
            let by_rows: f32 = (0..4).map(|b| ath_rows[b * p + k]).sum();
            assert!((by_rows - summed).abs() < 1e-5, "θ[{k}]");
        }
    }

    /// LinearToy's vectorized batched override is elementwise-identical to
    /// the fallback and counts one evaluation per row.
    #[test]
    fn linear_toy_batched_override_matches_fallback() {
        let toy = LinearToy::new(0.7, 2);
        let spec = BatchSpec::new(3, 2);
        let z = [1.0f32, -2.0, 0.5, 4.0, -1.0, 3.0];
        let ts = [0.0, 1.0, 2.0];
        let fb = toy.f_batch(&ts, &z, &spec);
        for (fi, &zi) in fb.iter().zip(&z) {
            assert_eq!(*fi, 0.7f32 * zi);
        }
        assert_eq!(toy.counters().f_evals.get(), 3, "counts per-row evals");
        let a = [1.0f32; 6];
        let (az, ath) = toy.f_vjp_batch(&ts, &z, &a, &spec);
        assert_eq!(az.len(), 6);
        // dθ = Σ_rows Σ_i a z = (1−2) + (0.5+4) + (−1+3) = 5.5
        assert!((ath[0] - 5.5).abs() < 1e-5);
        let (_, ath_rows) = toy.f_vjp_batch_rows(&ts, &z, &a, &spec);
        assert_eq!(ath_rows.len(), 3);
        assert!((ath_rows[0] + 1.0).abs() < 1e-6);
        assert!((ath_rows[1] - 4.5).abs() < 1e-6);
        assert!((ath_rows[2] - 2.0).abs() < 1e-6);
    }

    /// The `_into` entry points (LinearToy's allocation-free overrides and
    /// the forwarding defaults) write exactly what the allocating methods
    /// return, and count evaluations identically.
    #[test]
    fn into_entry_points_match_allocating() {
        let toy = LinearToy::new(0.7, 3);
        let spec = BatchSpec::new(2, 3);
        let z = [0.5f32, -1.0, 2.0, 0.25, 4.0, -3.0];
        let a = [1.0f32, -0.5, 0.25, 2.0, 0.0, 1.5];
        let ts = [0.0, 1.0];

        let want = toy.f(0.3, &z[..3]);
        let mut out = vec![9.0f32; 3];
        toy.f_into(0.3, &z[..3], &mut out);
        assert_eq!(out, want);

        let (az_want, ath_want) = toy.f_vjp(0.3, &z[..3], &a[..3]);
        let mut az = vec![0.0f32; 3];
        let mut ath = vec![0.0f32; 1];
        toy.f_vjp_into(0.3, &z[..3], &a[..3], &mut az, &mut ath);
        assert_eq!(az, az_want);
        assert_eq!(ath, ath_want);

        let want = toy.f_batch(&ts, &z, &spec);
        let mut out = vec![0.0f32; 6];
        toy.f_batch_into(&ts, &z, &spec, &mut out);
        assert_eq!(out, want);

        let (az_want, ath_want) = toy.f_vjp_batch(&ts, &z, &a, &spec);
        let mut az = vec![0.0f32; 6];
        let mut ath = vec![0.0f32; 1];
        toy.f_vjp_batch_into(&ts, &z, &a, &spec, &mut az, &mut ath);
        assert_eq!(az, az_want);
        assert_eq!(ath, ath_want);
        // every evaluation above was counted exactly once per sample unit
        assert_eq!(toy.counters().f_evals.get(), 2 + 2 * 2);
        assert_eq!(toy.counters().vjp_evals.get(), 2 + 2 * 2);

        // forwarding defaults on a dynamics without overrides
        let mut rng = Rng::new(3);
        let mlp = MlpDynamics::new(2, 3, &mut rng);
        let zz = [0.2f32, -0.4];
        let aa = [1.0f32, 0.5];
        let want = mlp.f(0.1, &zz);
        let mut out = vec![0.0f32; 2];
        mlp.f_into(0.1, &zz, &mut out);
        assert_eq!(out, want);
        let (az_want, ath_want) = mlp.f_vjp(0.1, &zz, &aa);
        let mut az = vec![0.0f32; 2];
        let mut ath = vec![0.0f32; mlp.param_dim()];
        mlp.f_vjp_into(0.1, &zz, &aa, &mut az, &mut ath);
        assert_eq!(az, az_want);
        assert_eq!(ath, ath_want);
    }

    #[test]
    fn counters_accumulate() {
        let toy = LinearToy::new(1.0, 1);
        toy.f(0.0, &[1.0]);
        toy.f(0.0, &[1.0]);
        toy.f_vjp(0.0, &[1.0], &[1.0]);
        assert_eq!(toy.counters().f_evals.get(), 2);
        assert_eq!(toy.counters().vjp_evals.get(), 1);
        toy.counters().reset();
        assert_eq!(toy.counters().f_evals.get(), 0);
    }
}
