//! Explicit Runge–Kutta solvers (tableau-driven) with embedded error
//! estimates and a generic step-vjp.
//!
//! These are (a) the baselines MALI is compared against and (b) the
//! inference solvers of the invariance-to-discretization experiment
//! (paper Table 2): Euler, Midpoint(RK2), RK4, Heun–Euler 2(1),
//! Bogacki–Shampine RK23 3(2) and Dormand–Prince Dopri5 5(4) — the
//! `torchdiffeq` default the paper tests with.

use super::batch::{BatchSpec, BatchState};
use super::dynamics::Dynamics;
use super::{Solver, State};
use crate::tensor::{axpy, axpy_rows, lincomb};

/// Butcher tableau of an explicit method, optionally with an embedded
/// lower-order weight row for error estimation.
#[derive(Debug, Clone)]
pub struct Tableau {
    pub name: &'static str,
    pub order: usize,
    pub c: Vec<f64>,
    /// Strictly lower-triangular a[i][j], j < i.
    pub a: Vec<Vec<f64>>,
    pub b: Vec<f64>,
    /// Embedded weights b̂ (error = h·Σ (b−b̂)·k); None for fixed-order use.
    pub b_low: Option<Vec<f64>>,
}

impl Tableau {
    pub fn euler() -> Tableau {
        Tableau {
            name: "euler",
            order: 1,
            c: vec![0.0],
            a: vec![vec![]],
            b: vec![1.0],
            b_low: None,
        }
    }

    /// Explicit midpoint — the integrator ALF is contrasted with in §3.1.
    pub fn midpoint() -> Tableau {
        Tableau {
            name: "midpoint",
            order: 2,
            c: vec![0.0, 0.5],
            a: vec![vec![], vec![0.5]],
            b: vec![0.0, 1.0],
            b_low: None,
        }
    }

    pub fn rk4() -> Tableau {
        Tableau {
            name: "rk4",
            order: 4,
            c: vec![0.0, 0.5, 0.5, 1.0],
            a: vec![vec![], vec![0.5], vec![0.0, 0.5], vec![0.0, 0.0, 1.0]],
            b: vec![1.0 / 6.0, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 6.0],
            b_low: None,
        }
    }

    /// Heun–Euler 2(1) — ACA's training solver in the paper's Cifar10 setup.
    pub fn heun_euler() -> Tableau {
        Tableau {
            name: "heun-euler",
            order: 2,
            c: vec![0.0, 1.0],
            a: vec![vec![], vec![1.0]],
            b: vec![0.5, 0.5],
            b_low: Some(vec![1.0, 0.0]),
        }
    }

    /// Bogacki–Shampine 3(2).
    pub fn rk23() -> Tableau {
        Tableau {
            name: "rk23",
            order: 3,
            c: vec![0.0, 0.5, 0.75, 1.0],
            a: vec![
                vec![],
                vec![0.5],
                vec![0.0, 0.75],
                vec![2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0],
            ],
            b: vec![2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0, 0.0],
            b_low: Some(vec![7.0 / 24.0, 0.25, 1.0 / 3.0, 0.125]),
        }
    }

    /// Dormand–Prince 5(4), the `torchdiffeq` default.
    pub fn dopri5() -> Tableau {
        Tableau {
            name: "dopri5",
            order: 5,
            c: vec![0.0, 0.2, 0.3, 0.8, 8.0 / 9.0, 1.0, 1.0],
            a: vec![
                vec![],
                vec![0.2],
                vec![3.0 / 40.0, 9.0 / 40.0],
                vec![44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0],
                vec![
                    19372.0 / 6561.0,
                    -25360.0 / 2187.0,
                    64448.0 / 6561.0,
                    -212.0 / 729.0,
                ],
                vec![
                    9017.0 / 3168.0,
                    -355.0 / 33.0,
                    46732.0 / 5247.0,
                    49.0 / 176.0,
                    -5103.0 / 18656.0,
                ],
                vec![
                    35.0 / 384.0,
                    0.0,
                    500.0 / 1113.0,
                    125.0 / 192.0,
                    -2187.0 / 6784.0,
                    11.0 / 84.0,
                ],
            ],
            b: vec![
                35.0 / 384.0,
                0.0,
                500.0 / 1113.0,
                125.0 / 192.0,
                -2187.0 / 6784.0,
                11.0 / 84.0,
                0.0,
            ],
            b_low: Some(vec![
                5179.0 / 57600.0,
                0.0,
                7571.0 / 16695.0,
                393.0 / 640.0,
                -92097.0 / 339200.0,
                187.0 / 2100.0,
                1.0 / 40.0,
            ]),
        }
    }
}

#[derive(Debug, Clone)]
pub struct RkSolver {
    pub tab: Tableau,
}

impl RkSolver {
    pub fn new(tab: Tableau) -> Self {
        RkSolver { tab }
    }

    /// Evaluate all stages `k_i` and stage inputs `y_i`.
    fn stages(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        z: &[f32],
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let s = self.tab.b.len();
        let mut ks: Vec<Vec<f32>> = Vec::with_capacity(s);
        let mut ys: Vec<Vec<f32>> = Vec::with_capacity(s);
        for i in 0..s {
            let mut y = z.to_vec();
            for (j, &aij) in self.tab.a[i].iter().enumerate() {
                if aij != 0.0 {
                    axpy((h * aij) as f32, &ks[j], &mut y);
                }
            }
            let k = dynamics.f(t + self.tab.c[i] * h, &y);
            ys.push(y);
            ks.push(k);
        }
        (ks, ys)
    }

    /// Per-row `(h_b · coeff) as f32` scale vector for batched stage
    /// arithmetic — the same cast order as the solo `(h * aij) as f32`.
    fn row_coeffs(hs: &[f64], coeff: f64) -> Vec<f32> {
        hs.iter().map(|&h| (h * coeff) as f32).collect()
    }

    /// Batched stage evaluation over the flat `[B·N_z]` buffer with
    /// per-row `(t, h)`: one `f_batch` call per stage regardless of B.
    fn stages_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        z: &[f32],
        spec: &BatchSpec,
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let s = self.tab.b.len();
        let mut ks: Vec<Vec<f32>> = Vec::with_capacity(s);
        let mut ys: Vec<Vec<f32>> = Vec::with_capacity(s);
        for i in 0..s {
            let mut y = z.to_vec();
            for (j, &aij) in self.tab.a[i].iter().enumerate() {
                if aij != 0.0 {
                    axpy_rows(&Self::row_coeffs(hs, aij), &ks[j], &mut y, spec.n_z);
                }
            }
            let stage_ts: Vec<f64> = ts
                .iter()
                .zip(hs)
                .map(|(&t, &h)| t + self.tab.c[i] * h)
                .collect();
            let k = dynamics.f_batch(&stage_ts, &y, spec);
            ys.push(y);
            ks.push(k);
        }
        (ks, ys)
    }
}

impl Solver for RkSolver {
    fn name(&self) -> &'static str {
        self.tab.name
    }

    fn order(&self) -> usize {
        self.tab.order
    }

    fn has_error_estimate(&self) -> bool {
        self.tab.b_low.is_some()
    }

    fn init(&self, _dynamics: &dyn Dynamics, _t0: f64, z0: &[f32]) -> State {
        State {
            z: z0.to_vec(),
            v: None,
        }
    }

    fn step(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s: &State,
    ) -> (State, Option<Vec<f32>>) {
        let (ks, _ys) = self.stages(dynamics, t, h, &s.z);
        let mut z1 = s.z.clone();
        for (i, &bi) in self.tab.b.iter().enumerate() {
            if bi != 0.0 {
                axpy((h * bi) as f32, &ks[i], &mut z1);
            }
        }
        let err = self.tab.b_low.as_ref().map(|bl| {
            let terms: Vec<(f32, &[f32])> = self
                .tab
                .b
                .iter()
                .zip(bl)
                .enumerate()
                .map(|(i, (&b, &bh))| ((h * (b - bh)) as f32, ks[i].as_slice()))
                .collect();
            lincomb(&terms)
        });
        (State { z: z1, v: None }, err)
    }

    /// Reverse-mode through one RK step: cotangent `a_out.z` on `z'`
    /// propagates back through every stage.  (The embedded error output is
    /// control flow, not a differentiated quantity — matching ACA/MALI's
    /// "backprop only through the accepted step".)
    fn step_vjp(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s_in: &State,
        a_out: &State,
    ) -> (State, Vec<f32>) {
        let (ks, ys) = self.stages(dynamics, t, h, &s_in.z);
        let nstages = ks.len();
        let az_out = &a_out.z;
        // a_k[i] starts at h·b_i·a_z'
        let mut a_k: Vec<Vec<f32>> = self
            .tab
            .b
            .iter()
            .map(|&bi| az_out.iter().map(|&a| (h * bi) as f32 * a).collect())
            .collect();
        let mut a_z = az_out.clone();
        let mut a_theta = vec![0.0f32; dynamics.param_dim()];
        for i in (0..nstages).rev() {
            if a_k[i].iter().all(|&x| x == 0.0) {
                continue;
            }
            let (g_y, g_th) = dynamics.f_vjp(t + self.tab.c[i] * h, &ys[i], &a_k[i]);
            axpy(1.0, &g_th, &mut a_theta);
            // y_i = z + h Σ_j a_ij k_j
            axpy(1.0, &g_y, &mut a_z);
            for (j, &aij) in self.tab.a[i].iter().enumerate() {
                if aij != 0.0 {
                    let coeff = (h * aij) as f32;
                    for (akj, gy) in a_k[j].iter_mut().zip(&g_y) {
                        *akj += coeff * gy;
                    }
                }
            }
        }
        (State { z: a_z, v: None }, a_theta)
    }

    fn invert(
        &self,
        _dynamics: &dyn Dynamics,
        _t_out: f64,
        _h: f64,
        _s_out: &State,
    ) -> Option<State> {
        None // RK steps have no closed-form inverse — that's MALI's point.
    }

    // ---- batched path ---------------------------------------------------

    fn init_batch(
        &self,
        _dynamics: &dyn Dynamics,
        _t0: f64,
        z0: &[f32],
        spec: &BatchSpec,
    ) -> BatchState {
        BatchState::from_flat(z0.to_vec(), *spec)
    }

    fn step_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s: &BatchState,
    ) -> (BatchState, Option<Vec<f32>>) {
        let spec = s.spec();
        let (ks, _ys) = self.stages_batch(dynamics, ts, hs, &s.z.data, &spec);
        let mut z1 = s.z.data.clone();
        for (i, &bi) in self.tab.b.iter().enumerate() {
            if bi != 0.0 {
                axpy_rows(&Self::row_coeffs(hs, bi), &ks[i], &mut z1, spec.n_z);
            }
        }
        let err = self.tab.b_low.as_ref().map(|bl| {
            let mut e = vec![0.0f32; spec.flat_len()];
            for (i, (&b, &bh)) in self.tab.b.iter().zip(bl).enumerate() {
                axpy_rows(&Self::row_coeffs(hs, b - bh), &ks[i], &mut e, spec.n_z);
            }
            e
        });
        (BatchState::from_flat(z1, spec), err)
    }

    fn step_vjp_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s_in: &BatchState,
        a_out: &BatchState,
    ) -> (BatchState, Vec<f32>) {
        let spec = s_in.spec();
        let (_ks, ys) = self.stages_batch(dynamics, ts, hs, &s_in.z.data, &spec);
        let nstages = ys.len();
        let az_out = &a_out.z.data;
        // a_k[i] starts at h_b·b_i·a_z' per row
        let mut a_k: Vec<Vec<f32>> = self
            .tab
            .b
            .iter()
            .map(|&bi| {
                let coeffs = Self::row_coeffs(hs, bi);
                let mut buf = Vec::with_capacity(spec.flat_len());
                for b in 0..spec.batch {
                    let c = coeffs[b];
                    buf.extend(spec.row(az_out, b).iter().map(|&a| c * a));
                }
                buf
            })
            .collect();
        let mut a_z = az_out.clone();
        let mut a_theta = vec![0.0f32; dynamics.param_dim()];
        for i in (0..nstages).rev() {
            // Per-row zero-cotangent skip, matching the solo path's
            // per-sample stage skip — rows with a zero a_k[i] row are
            // excluded from the vjp call, so per-sample vjp-eval counts
            // equal B solo runs (their g_y contribution is exactly zero).
            let nz: Vec<usize> = (0..spec.batch)
                .filter(|&b| spec.row(&a_k[i], b).iter().any(|&x| x != 0.0))
                .collect();
            if nz.is_empty() {
                continue;
            }
            let stage_ts: Vec<f64> = ts
                .iter()
                .zip(hs)
                .map(|(&t, &h)| t + self.tab.c[i] * h)
                .collect();
            let (g_y, g_th) = if nz.len() == spec.batch {
                dynamics.f_vjp_batch(&stage_ts, &ys[i], &a_k[i], &spec)
            } else {
                let sub = spec.with_batch(nz.len());
                let ts_sub: Vec<f64> = nz.iter().map(|&b| stage_ts[b]).collect();
                let y_sub = spec.gather(&ys[i], &nz);
                let ak_sub = spec.gather(&a_k[i], &nz);
                let (gy_sub, g_th) = dynamics.f_vjp_batch(&ts_sub, &y_sub, &ak_sub, &sub);
                let mut g_y = vec![0.0f32; spec.flat_len()];
                spec.scatter(&gy_sub, &nz, &mut g_y);
                (g_y, g_th)
            };
            axpy(1.0, &g_th, &mut a_theta);
            // y_i = z + h Σ_j a_ij k_j
            axpy(1.0, &g_y, &mut a_z);
            for (j, &aij) in self.tab.a[i].iter().enumerate() {
                if aij != 0.0 {
                    axpy_rows(&Self::row_coeffs(hs, aij), &g_y, &mut a_k[j], spec.n_z);
                }
            }
        }
        (BatchState::from_flat(a_z, spec), a_theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::dynamics::{LinearToy, MlpDynamics};
    use crate::util::rng::Rng;

    fn one_step_err(tab: Tableau, h: f64) -> f64 {
        let toy = LinearToy::new(1.0, 1);
        let solver = RkSolver::new(tab);
        let s0 = solver.init(&toy, 0.0, &[1.0]);
        let (s1, _) = solver.step(&toy, 0.0, h, &s0);
        ((s1.z[0] as f64) - h.exp()).abs()
    }

    /// Empirical one-step convergence order: err(h)/err(h/2) ≈ 2^(p+1).
    #[test]
    fn convergence_orders() {
        for (tab, p) in [
            (Tableau::euler(), 1usize),
            (Tableau::midpoint(), 2),
            (Tableau::heun_euler(), 2),
            (Tableau::rk23(), 3),
            (Tableau::rk4(), 4),
            (Tableau::dopri5(), 5),
        ] {
            let name = tab.name;
            // High-order methods need larger h so the one-step error stays
            // above the f32 roundoff floor.
            let h = if p >= 4 { 0.8 } else { 0.2 };
            let e1 = one_step_err(tab.clone(), h);
            let e2 = one_step_err(tab, h / 2.0);
            let ratio = e1 / e2.max(1e-300);
            // Ideal one-step decay is 2^(p+1); with f32 state the high-order
            // pairs sit near the roundoff floor, so accept clear separation
            // from order p−1 instead of the asymptotic constant.
            let expect = 2f64.powi(p as i32 + 1);
            let floor = (expect * 0.5).min(2f64.powi(p as i32) * 0.8);
            assert!(
                ratio > floor,
                "{name}: ratio {ratio:.2}, expected ≥ {floor:.2}"
            );
        }
    }

    #[test]
    fn tableau_consistency() {
        for tab in [
            Tableau::euler(),
            Tableau::midpoint(),
            Tableau::rk4(),
            Tableau::heun_euler(),
            Tableau::rk23(),
            Tableau::dopri5(),
        ] {
            let s = tab.b.len();
            assert_eq!(tab.c.len(), s);
            assert_eq!(tab.a.len(), s);
            for (i, row) in tab.a.iter().enumerate() {
                assert!(row.len() <= i, "{}: a not lower triangular", tab.name);
                // c_i = Σ_j a_ij (stage consistency)
                let ci: f64 = row.iter().sum();
                assert!(
                    (ci - tab.c[i]).abs() < 1e-12,
                    "{}: c[{i}] {} vs Σa {}",
                    tab.name,
                    tab.c[i],
                    ci
                );
            }
            // Σ b = 1
            assert!((tab.b.iter().sum::<f64>() - 1.0).abs() < 1e-12);
            if let Some(bl) = &tab.b_low {
                assert!((bl.iter().sum::<f64>() - 1.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn embedded_error_scales_with_h() {
        let toy = LinearToy::new(1.0, 1);
        let solver = RkSolver::new(Tableau::dopri5());
        let s0 = solver.init(&toy, 0.0, &[1.0]);
        let (_, e1) = solver.step(&toy, 0.0, 0.2, &s0);
        let (_, e2) = solver.step(&toy, 0.0, 0.1, &s0);
        let (e1, e2) = (e1.unwrap()[0].abs() as f64, e2.unwrap()[0].abs() as f64);
        assert!(e1 > e2, "error estimate should shrink with h: {e1} vs {e2}");
    }

    /// Batched RK step / step-vjp with desynchronized per-row `(t, h)`
    /// equals the single-sample methods row-for-row.
    #[test]
    fn batched_step_matches_rows_exactly() {
        use crate::solvers::batch::{BatchSpec, BatchState};
        let mut rng = Rng::new(23);
        for tab in [Tableau::rk4(), Tableau::dopri5(), Tableau::heun_euler()] {
            let name = tab.name;
            let dynamics = MlpDynamics::new(2, 4, &mut rng);
            let solver = RkSolver::new(tab);
            let spec = BatchSpec::new(3, 2);
            let mut z = vec![0.0f32; spec.flat_len()];
            rng.fill_normal(&mut z, 0.5);
            let ts = [0.0, 0.4, 1.1];
            let hs = [0.2, 0.35, 0.07];
            let bs = BatchState::from_flat(z.clone(), spec);
            let (next, err) = solver.step_batch(&dynamics, &ts, &hs, &bs);
            for b in 0..3 {
                let s0 = State {
                    z: spec.row(&z, b).to_vec(),
                    v: None,
                };
                let (s1, e1) = solver.step(&dynamics, ts[b], hs[b], &s0);
                assert_eq!(spec.row(&next.z.data, b), s1.z.as_slice(), "{name} z row {b}");
                match (&err, e1) {
                    (Some(eb), Some(es)) => {
                        assert_eq!(spec.row(eb, b), es.as_slice(), "{name} err row {b}")
                    }
                    (None, None) => {}
                    _ => panic!("{name}: err presence mismatch"),
                }
            }
            // vjp
            let mut az = vec![0.0f32; spec.flat_len()];
            rng.fill_normal(&mut az, 1.0);
            let a_out = BatchState::from_flat(az.clone(), spec);
            let (a_in, ath) = solver.step_vjp_batch(&dynamics, &ts, &hs, &bs, &a_out);
            let mut ath_sum = vec![0.0f32; dynamics.param_dim()];
            for b in 0..3 {
                let s0 = State {
                    z: spec.row(&z, b).to_vec(),
                    v: None,
                };
                let a0 = State {
                    z: spec.row(&az, b).to_vec(),
                    v: None,
                };
                let (a_b, ath_b) = solver.step_vjp(&dynamics, ts[b], hs[b], &s0, &a0);
                assert_eq!(
                    spec.row(&a_in.z.data, b),
                    a_b.z.as_slice(),
                    "{name} a_z row {b}"
                );
                axpy(1.0, &ath_b, &mut ath_sum);
            }
            for (k, (&got, &want)) in ath.iter().zip(&ath_sum).enumerate() {
                assert!(
                    (got - want).abs() < 1e-4,
                    "{name} a_θ[{k}]: {got} vs {want}"
                );
            }
        }
    }

    /// Generic RK step-vjp against central finite differences, for a
    /// representative adaptive (dopri5) and fixed (rk4) tableau.
    #[test]
    fn step_vjp_matches_finite_differences() {
        let mut rng = Rng::new(17);
        for tab in [Tableau::rk4(), Tableau::dopri5(), Tableau::heun_euler()] {
            let name = tab.name;
            let mut dynamics = MlpDynamics::new(3, 4, &mut rng);
            let solver = RkSolver::new(tab);
            let (t, h) = (0.2, 0.3);
            let z = vec![0.1f32, -0.4, 0.6];
            let az_out = vec![1.0f32, 0.5, -0.7];
            let s_in = State {
                z: z.clone(),
                v: None,
            };
            let a_out = State {
                z: az_out.clone(),
                v: None,
            };
            let (a_in, a_th) = solver.step_vjp(&dynamics, t, h, &s_in, &a_out);

            let scalar = |zz: &[f32], d: &MlpDynamics| -> f64 {
                let (s1, _) = solver.step(
                    d,
                    t,
                    h,
                    &State {
                        z: zz.to_vec(),
                        v: None,
                    },
                );
                s1.z.iter()
                    .zip(&az_out)
                    .map(|(&x, &c)| x as f64 * c as f64)
                    .sum()
            };
            let eps = 1e-3;
            for j in 0..z.len() {
                let mut zp = z.clone();
                zp[j] += eps as f32;
                let mut zm = z.clone();
                zm[j] -= eps as f32;
                let fd = (scalar(&zp, &dynamics) - scalar(&zm, &dynamics)) / (2.0 * eps);
                assert!(
                    (fd - a_in.z[j] as f64).abs() < 5e-3,
                    "{name} a_z[{j}]: {fd} vs {}",
                    a_in.z[j]
                );
            }
            let theta0 = dynamics.params().to_vec();
            for &k in &[0usize, theta0.len() / 2, theta0.len() - 1] {
                let mut tp = theta0.clone();
                tp[k] += eps as f32;
                dynamics.set_params(&tp);
                let fp = scalar(&z, &dynamics);
                let mut tm = theta0.clone();
                tm[k] -= eps as f32;
                dynamics.set_params(&tm);
                let fm = scalar(&z, &dynamics);
                dynamics.set_params(&theta0);
                let fd = (fp - fm) / (2.0 * eps);
                assert!(
                    (fd - a_th[k] as f64).abs() < 5e-3,
                    "{name} a_θ[{k}]: {fd} vs {}",
                    a_th[k]
                );
            }
        }
    }
}
