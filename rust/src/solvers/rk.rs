//! Explicit Runge–Kutta solvers (tableau-driven) with embedded error
//! estimates and a generic step-vjp.
//!
//! These are (a) the baselines MALI is compared against and (b) the
//! inference solvers of the invariance-to-discretization experiment
//! (paper Table 2): Euler, Midpoint(RK2), RK4, Heun–Euler 2(1),
//! Bogacki–Shampine RK23 3(2) and Dormand–Prince Dopri5 5(4) — the
//! `torchdiffeq` default the paper tests with.

use super::batch::{BatchSpec, BatchState};
use super::dynamics::Dynamics;
use super::workspace::{
    ensure, ensure_stages, fill_row_coeffs, fill_stage_times, shape_state_n, BatchWorkspace,
    SolverWorkspace,
};
use super::{Solver, State};
use crate::tensor::{axpy, axpy_rows};

/// Butcher tableau of an explicit method, optionally with an embedded
/// lower-order weight row for error estimation.
#[derive(Debug, Clone)]
pub struct Tableau {
    pub name: &'static str,
    pub order: usize,
    pub c: Vec<f64>,
    /// Strictly lower-triangular a[i][j], j < i.
    pub a: Vec<Vec<f64>>,
    pub b: Vec<f64>,
    /// Embedded weights b̂ (error = h·Σ (b−b̂)·k); None for fixed-order use.
    pub b_low: Option<Vec<f64>>,
}

impl Tableau {
    pub fn euler() -> Tableau {
        Tableau {
            name: "euler",
            order: 1,
            c: vec![0.0],
            a: vec![vec![]],
            b: vec![1.0],
            b_low: None,
        }
    }

    /// Explicit midpoint — the integrator ALF is contrasted with in §3.1.
    pub fn midpoint() -> Tableau {
        Tableau {
            name: "midpoint",
            order: 2,
            c: vec![0.0, 0.5],
            a: vec![vec![], vec![0.5]],
            b: vec![0.0, 1.0],
            b_low: None,
        }
    }

    pub fn rk4() -> Tableau {
        Tableau {
            name: "rk4",
            order: 4,
            c: vec![0.0, 0.5, 0.5, 1.0],
            a: vec![vec![], vec![0.5], vec![0.0, 0.5], vec![0.0, 0.0, 1.0]],
            b: vec![1.0 / 6.0, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 6.0],
            b_low: None,
        }
    }

    /// Heun–Euler 2(1) — ACA's training solver in the paper's Cifar10 setup.
    pub fn heun_euler() -> Tableau {
        Tableau {
            name: "heun-euler",
            order: 2,
            c: vec![0.0, 1.0],
            a: vec![vec![], vec![1.0]],
            b: vec![0.5, 0.5],
            b_low: Some(vec![1.0, 0.0]),
        }
    }

    /// Bogacki–Shampine 3(2).
    pub fn rk23() -> Tableau {
        Tableau {
            name: "rk23",
            order: 3,
            c: vec![0.0, 0.5, 0.75, 1.0],
            a: vec![
                vec![],
                vec![0.5],
                vec![0.0, 0.75],
                vec![2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0],
            ],
            b: vec![2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0, 0.0],
            b_low: Some(vec![7.0 / 24.0, 0.25, 1.0 / 3.0, 0.125]),
        }
    }

    /// Dormand–Prince 5(4), the `torchdiffeq` default.
    pub fn dopri5() -> Tableau {
        Tableau {
            name: "dopri5",
            order: 5,
            c: vec![0.0, 0.2, 0.3, 0.8, 8.0 / 9.0, 1.0, 1.0],
            a: vec![
                vec![],
                vec![0.2],
                vec![3.0 / 40.0, 9.0 / 40.0],
                vec![44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0],
                vec![
                    19372.0 / 6561.0,
                    -25360.0 / 2187.0,
                    64448.0 / 6561.0,
                    -212.0 / 729.0,
                ],
                vec![
                    9017.0 / 3168.0,
                    -355.0 / 33.0,
                    46732.0 / 5247.0,
                    49.0 / 176.0,
                    -5103.0 / 18656.0,
                ],
                vec![
                    35.0 / 384.0,
                    0.0,
                    500.0 / 1113.0,
                    125.0 / 192.0,
                    -2187.0 / 6784.0,
                    11.0 / 84.0,
                ],
            ],
            b: vec![
                35.0 / 384.0,
                0.0,
                500.0 / 1113.0,
                125.0 / 192.0,
                -2187.0 / 6784.0,
                11.0 / 84.0,
                0.0,
            ],
            b_low: Some(vec![
                5179.0 / 57600.0,
                0.0,
                7571.0 / 16695.0,
                393.0 / 640.0,
                -92097.0 / 339200.0,
                187.0 / 2100.0,
                1.0 / 40.0,
            ]),
        }
    }
}

#[derive(Debug, Clone)]
pub struct RkSolver {
    pub tab: Tableau,
}

impl RkSolver {
    pub fn new(tab: Tableau) -> Self {
        RkSolver { tab }
    }

    /// Evaluate all stages into `ws.ks` / `ws.ys` (the first `s` buffers
    /// of each).  The stage inputs were previously cloned from `z` per
    /// stage; the workspace path copies into preallocated buffers — same
    /// arithmetic, zero steady-state allocations.
    fn stages_into(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        z: &[f32],
        ws: &mut SolverWorkspace,
    ) {
        let s = self.tab.b.len();
        let n = z.len();
        ensure_stages(&mut ws.ks, s, n);
        ensure_stages(&mut ws.ys, s, n);
        for i in 0..s {
            ws.ys[i].copy_from_slice(z);
            for (j, &aij) in self.tab.a[i].iter().enumerate() {
                if aij != 0.0 {
                    axpy((h * aij) as f32, &ws.ks[j], &mut ws.ys[i]);
                }
            }
            dynamics.f_into(t + self.tab.c[i] * h, &ws.ys[i], &mut ws.ks[i]);
        }
    }

    /// Batched stage evaluation into `ws.ks` / `ws.ys` over the flat
    /// `[B·N_z]` buffer with per-row `(t, h)`: one `f_batch` call per
    /// stage regardless of B.
    fn stages_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        z: &[f32],
        spec: &BatchSpec,
        ws: &mut BatchWorkspace,
    ) {
        let s = self.tab.b.len();
        let n = spec.flat_len();
        ensure_stages(&mut ws.ks, s, n);
        ensure_stages(&mut ws.ys, s, n);
        for i in 0..s {
            ws.ys[i].copy_from_slice(z);
            for (j, &aij) in self.tab.a[i].iter().enumerate() {
                if aij != 0.0 {
                    fill_row_coeffs(hs, aij, &mut ws.coeffs);
                    axpy_rows(&ws.coeffs, &ws.ks[j], &mut ws.ys[i], spec.n_z);
                }
            }
            fill_stage_times(ts, hs, self.tab.c[i], &mut ws.s1s);
            dynamics.f_batch_into(&ws.s1s, &ws.ys[i], spec, &mut ws.ks[i]);
        }
    }
}

impl Solver for RkSolver {
    fn name(&self) -> &'static str {
        self.tab.name
    }

    fn order(&self) -> usize {
        self.tab.order
    }

    fn has_error_estimate(&self) -> bool {
        self.tab.b_low.is_some()
    }

    fn init(&self, _dynamics: &dyn Dynamics, _t0: f64, z0: &[f32]) -> State {
        State {
            z: z0.to_vec(),
            v: None,
        }
    }

    fn step(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s: &State,
    ) -> (State, Option<Vec<f32>>) {
        let mut ws = SolverWorkspace::new();
        let mut out = State {
            z: Vec::new(),
            v: None,
        };
        let mut err = Vec::new();
        let has_err = self.step_into(dynamics, t, h, s, &mut out, &mut err, &mut ws);
        (out, has_err.then_some(err))
    }

    /// Reverse-mode through one RK step: cotangent `a_out.z` on `z'`
    /// propagates back through every stage.  (The embedded error output is
    /// control flow, not a differentiated quantity — matching ACA/MALI's
    /// "backprop only through the accepted step".)
    fn step_vjp(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s_in: &State,
        a_out: &State,
    ) -> (State, Vec<f32>) {
        let mut ws = SolverWorkspace::new();
        let mut a_in = State {
            z: Vec::new(),
            v: None,
        };
        let mut a_theta = vec![0.0f32; dynamics.param_dim()];
        self.step_vjp_into(dynamics, t, h, s_in, a_out, &mut a_in, &mut a_theta, &mut ws);
        (a_in, a_theta)
    }

    fn step_into(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s: &State,
        out: &mut State,
        err: &mut Vec<f32>,
        ws: &mut SolverWorkspace,
    ) -> bool {
        let n = s.z.len();
        self.stages_into(dynamics, t, h, &s.z, ws);
        shape_state_n(out, n, false);
        out.z.copy_from_slice(&s.z);
        for (i, &bi) in self.tab.b.iter().enumerate() {
            if bi != 0.0 {
                axpy((h * bi) as f32, &ws.ks[i], &mut out.z);
            }
        }
        match &self.tab.b_low {
            Some(bl) => {
                // err = h·Σ (b−b̂)·k — zero-fill then accumulate term by
                // term in stage order, exactly like the old `lincomb`
                ensure(err, n);
                err.fill(0.0);
                for (i, (&b, &bh)) in self.tab.b.iter().zip(bl).enumerate() {
                    axpy((h * (b - bh)) as f32, &ws.ks[i], err);
                }
                true
            }
            None => false,
        }
    }

    fn step_vjp_into(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s_in: &State,
        a_out: &State,
        a_in: &mut State,
        ath_acc: &mut [f32],
        ws: &mut SolverWorkspace,
    ) {
        let n = s_in.z.len();
        self.stages_into(dynamics, t, h, &s_in.z, ws);
        let nstages = self.tab.b.len();
        let az_out = &a_out.z;
        // a_k[i] starts at h·b_i·a_z'
        ensure_stages(&mut ws.a_k, nstages, n);
        for (i, &bi) in self.tab.b.iter().enumerate() {
            let coeff = (h * bi) as f32;
            for (o, &a) in ws.a_k[i].iter_mut().zip(az_out) {
                *o = coeff * a;
            }
        }
        shape_state_n(a_in, n, false);
        a_in.z.copy_from_slice(az_out);
        for i in (0..nstages).rev() {
            if ws.a_k[i].iter().all(|&x| x == 0.0) {
                continue;
            }
            ensure(&mut ws.g, n);
            dynamics.f_vjp_into(t + self.tab.c[i] * h, &ws.ys[i], &ws.a_k[i], &mut ws.g, ath_acc);
            // y_i = z + h Σ_j a_ij k_j
            axpy(1.0, &ws.g, &mut a_in.z);
            for (j, &aij) in self.tab.a[i].iter().enumerate() {
                if aij != 0.0 {
                    let coeff = (h * aij) as f32;
                    for (akj, gy) in ws.a_k[j].iter_mut().zip(&ws.g) {
                        *akj += coeff * gy;
                    }
                }
            }
        }
    }

    fn invert(
        &self,
        _dynamics: &dyn Dynamics,
        _t_out: f64,
        _h: f64,
        _s_out: &State,
    ) -> Option<State> {
        None // RK steps have no closed-form inverse — that's MALI's point.
    }

    // ---- batched path ---------------------------------------------------

    fn init_batch(
        &self,
        _dynamics: &dyn Dynamics,
        _t0: f64,
        z0: &[f32],
        spec: &BatchSpec,
    ) -> BatchState {
        BatchState::from_flat(z0.to_vec(), *spec)
    }

    fn init_batch_into(
        &self,
        _dynamics: &dyn Dynamics,
        _t0: f64,
        z0: &[f32],
        spec: &BatchSpec,
        out: &mut BatchState,
        _ws: &mut BatchWorkspace,
    ) {
        // Plain RK state: just `z₀` rows, no auxiliary buffer.
        crate::solvers::workspace::shape_batch_state(out, spec.batch, spec.n_z, false);
        out.z.data.copy_from_slice(z0);
    }

    fn step_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s: &BatchState,
    ) -> (BatchState, Option<Vec<f32>>) {
        let mut ws = BatchWorkspace::new();
        let mut out = BatchState::from_flat(vec![0.0f32; s.spec().flat_len()], s.spec());
        let mut err = Vec::new();
        let has_err = self.step_batch_into(dynamics, ts, hs, s, &mut out, &mut err, &mut ws);
        (out, has_err.then_some(err))
    }

    fn step_vjp_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s_in: &BatchState,
        a_out: &BatchState,
    ) -> (BatchState, Vec<f32>) {
        let mut ws = BatchWorkspace::new();
        let spec = s_in.spec();
        let mut a_in = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        let mut a_theta = vec![0.0f32; dynamics.param_dim()];
        self.step_vjp_batch_into(
            dynamics,
            ts,
            hs,
            s_in,
            a_out,
            &mut a_in,
            &mut a_theta,
            &mut ws,
        );
        (a_in, a_theta)
    }

    fn step_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s: &BatchState,
        out: &mut BatchState,
        err: &mut Vec<f32>,
        ws: &mut BatchWorkspace,
    ) -> bool {
        let spec = s.spec();
        self.stages_batch_into(dynamics, ts, hs, &s.z.data, &spec, ws);
        super::workspace::shape_batch_state(out, spec.batch, spec.n_z, false);
        out.z.data.copy_from_slice(&s.z.data);
        for (i, &bi) in self.tab.b.iter().enumerate() {
            if bi != 0.0 {
                fill_row_coeffs(hs, bi, &mut ws.coeffs);
                axpy_rows(&ws.coeffs, &ws.ks[i], &mut out.z.data, spec.n_z);
            }
        }
        match &self.tab.b_low {
            Some(bl) => {
                ensure(err, spec.flat_len());
                err.fill(0.0);
                for (i, (&b, &bh)) in self.tab.b.iter().zip(bl).enumerate() {
                    fill_row_coeffs(hs, b - bh, &mut ws.coeffs);
                    axpy_rows(&ws.coeffs, &ws.ks[i], err, spec.n_z);
                }
                true
            }
            None => false,
        }
    }

    fn step_vjp_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s_in: &BatchState,
        a_out: &BatchState,
        a_in: &mut BatchState,
        ath_acc: &mut [f32],
        ws: &mut BatchWorkspace,
    ) {
        let spec = s_in.spec();
        let n = spec.flat_len();
        self.stages_batch_into(dynamics, ts, hs, &s_in.z.data, &spec, ws);
        let nstages = self.tab.b.len();
        let az_out = &a_out.z.data;
        // a_k[i] starts at h_b·b_i·a_z' per row
        ensure_stages(&mut ws.a_k, nstages, n);
        for (i, &bi) in self.tab.b.iter().enumerate() {
            fill_row_coeffs(hs, bi, &mut ws.coeffs);
            for b in 0..spec.batch {
                let c = ws.coeffs[b];
                let lo = b * spec.n_z;
                for (o, &a) in ws.a_k[i][lo..lo + spec.n_z]
                    .iter_mut()
                    .zip(&az_out[lo..lo + spec.n_z])
                {
                    *o = c * a;
                }
            }
        }
        super::workspace::shape_batch_state(a_in, spec.batch, spec.n_z, false);
        a_in.z.data.copy_from_slice(az_out);
        for i in (0..nstages).rev() {
            // Per-row zero-cotangent skip, matching the solo path's
            // per-sample stage skip — rows with a zero a_k[i] row are
            // excluded from the vjp call, so per-sample vjp-eval counts
            // equal B solo runs (their g_y contribution is exactly zero).
            let nz: Vec<usize> = (0..spec.batch)
                .filter(|&b| spec.row(&ws.a_k[i], b).iter().any(|&x| x != 0.0))
                .collect();
            if nz.is_empty() {
                continue;
            }
            fill_stage_times(ts, hs, self.tab.c[i], &mut ws.s1s);
            ensure(&mut ws.g, n);
            if nz.len() == spec.batch {
                dynamics
                    .f_vjp_batch_into(&ws.s1s, &ws.ys[i], &ws.a_k[i], &spec, &mut ws.g, ath_acc);
            } else {
                // partial-row fallback (rare: only when some rows' stage
                // cotangent is exactly zero) — gathers allocate
                let sub = spec.with_batch(nz.len());
                let ts_sub: Vec<f64> = nz.iter().map(|&b| ws.s1s[b]).collect();
                let y_sub = spec.gather(&ws.ys[i], &nz);
                let ak_sub = spec.gather(&ws.a_k[i], &nz);
                let (gy_sub, g_th) = dynamics.f_vjp_batch(&ts_sub, &y_sub, &ak_sub, &sub);
                ws.g.fill(0.0);
                spec.scatter(&gy_sub, &nz, &mut ws.g);
                axpy(1.0, &g_th, ath_acc);
            }
            // y_i = z + h Σ_j a_ij k_j
            axpy(1.0, &ws.g, &mut a_in.z.data);
            for (j, &aij) in self.tab.a[i].iter().enumerate() {
                if aij != 0.0 {
                    fill_row_coeffs(hs, aij, &mut ws.coeffs);
                    axpy_rows(&ws.coeffs, &ws.g, &mut ws.a_k[j], spec.n_z);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::dynamics::{LinearToy, MlpDynamics};
    use crate::util::rng::Rng;

    fn one_step_err(tab: Tableau, h: f64) -> f64 {
        let toy = LinearToy::new(1.0, 1);
        let solver = RkSolver::new(tab);
        let s0 = solver.init(&toy, 0.0, &[1.0]);
        let (s1, _) = solver.step(&toy, 0.0, h, &s0);
        ((s1.z[0] as f64) - h.exp()).abs()
    }

    /// Empirical one-step convergence order: err(h)/err(h/2) ≈ 2^(p+1).
    #[test]
    fn convergence_orders() {
        for (tab, p) in [
            (Tableau::euler(), 1usize),
            (Tableau::midpoint(), 2),
            (Tableau::heun_euler(), 2),
            (Tableau::rk23(), 3),
            (Tableau::rk4(), 4),
            (Tableau::dopri5(), 5),
        ] {
            let name = tab.name;
            // High-order methods need larger h so the one-step error stays
            // above the f32 roundoff floor.
            let h = if p >= 4 { 0.8 } else { 0.2 };
            let e1 = one_step_err(tab.clone(), h);
            let e2 = one_step_err(tab, h / 2.0);
            let ratio = e1 / e2.max(1e-300);
            // Ideal one-step decay is 2^(p+1); with f32 state the high-order
            // pairs sit near the roundoff floor, so accept clear separation
            // from order p−1 instead of the asymptotic constant.
            let expect = 2f64.powi(p as i32 + 1);
            let floor = (expect * 0.5).min(2f64.powi(p as i32) * 0.8);
            assert!(
                ratio > floor,
                "{name}: ratio {ratio:.2}, expected ≥ {floor:.2}"
            );
        }
    }

    #[test]
    fn tableau_consistency() {
        for tab in [
            Tableau::euler(),
            Tableau::midpoint(),
            Tableau::rk4(),
            Tableau::heun_euler(),
            Tableau::rk23(),
            Tableau::dopri5(),
        ] {
            let s = tab.b.len();
            assert_eq!(tab.c.len(), s);
            assert_eq!(tab.a.len(), s);
            for (i, row) in tab.a.iter().enumerate() {
                assert!(row.len() <= i, "{}: a not lower triangular", tab.name);
                // c_i = Σ_j a_ij (stage consistency)
                let ci: f64 = row.iter().sum();
                assert!(
                    (ci - tab.c[i]).abs() < 1e-12,
                    "{}: c[{i}] {} vs Σa {}",
                    tab.name,
                    tab.c[i],
                    ci
                );
            }
            // Σ b = 1
            assert!((tab.b.iter().sum::<f64>() - 1.0).abs() < 1e-12);
            if let Some(bl) = &tab.b_low {
                assert!((bl.iter().sum::<f64>() - 1.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn embedded_error_scales_with_h() {
        let toy = LinearToy::new(1.0, 1);
        let solver = RkSolver::new(Tableau::dopri5());
        let s0 = solver.init(&toy, 0.0, &[1.0]);
        let (_, e1) = solver.step(&toy, 0.0, 0.2, &s0);
        let (_, e2) = solver.step(&toy, 0.0, 0.1, &s0);
        let (e1, e2) = (e1.unwrap()[0].abs() as f64, e2.unwrap()[0].abs() as f64);
        assert!(e1 > e2, "error estimate should shrink with h: {e1} vs {e2}");
    }

    /// Batched RK step / step-vjp with desynchronized per-row `(t, h)`
    /// equals the single-sample methods row-for-row.
    #[test]
    fn batched_step_matches_rows_exactly() {
        use crate::solvers::batch::{BatchSpec, BatchState};
        let mut rng = Rng::new(23);
        for tab in [Tableau::rk4(), Tableau::dopri5(), Tableau::heun_euler()] {
            let name = tab.name;
            let dynamics = MlpDynamics::new(2, 4, &mut rng);
            let solver = RkSolver::new(tab);
            let spec = BatchSpec::new(3, 2);
            let mut z = vec![0.0f32; spec.flat_len()];
            rng.fill_normal(&mut z, 0.5);
            let ts = [0.0, 0.4, 1.1];
            let hs = [0.2, 0.35, 0.07];
            let bs = BatchState::from_flat(z.clone(), spec);
            let (next, err) = solver.step_batch(&dynamics, &ts, &hs, &bs);
            for b in 0..3 {
                let s0 = State {
                    z: spec.row(&z, b).to_vec(),
                    v: None,
                };
                let (s1, e1) = solver.step(&dynamics, ts[b], hs[b], &s0);
                assert_eq!(spec.row(&next.z.data, b), s1.z.as_slice(), "{name} z row {b}");
                match (&err, e1) {
                    (Some(eb), Some(es)) => {
                        assert_eq!(spec.row(eb, b), es.as_slice(), "{name} err row {b}")
                    }
                    (None, None) => {}
                    _ => panic!("{name}: err presence mismatch"),
                }
            }
            // vjp
            let mut az = vec![0.0f32; spec.flat_len()];
            rng.fill_normal(&mut az, 1.0);
            let a_out = BatchState::from_flat(az.clone(), spec);
            let (a_in, ath) = solver.step_vjp_batch(&dynamics, &ts, &hs, &bs, &a_out);
            let mut ath_sum = vec![0.0f32; dynamics.param_dim()];
            for b in 0..3 {
                let s0 = State {
                    z: spec.row(&z, b).to_vec(),
                    v: None,
                };
                let a0 = State {
                    z: spec.row(&az, b).to_vec(),
                    v: None,
                };
                let (a_b, ath_b) = solver.step_vjp(&dynamics, ts[b], hs[b], &s0, &a0);
                assert_eq!(
                    spec.row(&a_in.z.data, b),
                    a_b.z.as_slice(),
                    "{name} a_z row {b}"
                );
                axpy(1.0, &ath_b, &mut ath_sum);
            }
            for (k, (&got, &want)) in ath.iter().zip(&ath_sum).enumerate() {
                assert!(
                    (got - want).abs() < 1e-4,
                    "{name} a_θ[{k}]: {got} vs {want}"
                );
            }
        }
    }

    /// Generic RK step-vjp against central finite differences, for a
    /// representative adaptive (dopri5) and fixed (rk4) tableau.
    #[test]
    fn step_vjp_matches_finite_differences() {
        let mut rng = Rng::new(17);
        for tab in [Tableau::rk4(), Tableau::dopri5(), Tableau::heun_euler()] {
            let name = tab.name;
            let mut dynamics = MlpDynamics::new(3, 4, &mut rng);
            let solver = RkSolver::new(tab);
            let (t, h) = (0.2, 0.3);
            let z = vec![0.1f32, -0.4, 0.6];
            let az_out = vec![1.0f32, 0.5, -0.7];
            let s_in = State {
                z: z.clone(),
                v: None,
            };
            let a_out = State {
                z: az_out.clone(),
                v: None,
            };
            let (a_in, a_th) = solver.step_vjp(&dynamics, t, h, &s_in, &a_out);

            let scalar = |zz: &[f32], d: &MlpDynamics| -> f64 {
                let (s1, _) = solver.step(
                    d,
                    t,
                    h,
                    &State {
                        z: zz.to_vec(),
                        v: None,
                    },
                );
                s1.z.iter()
                    .zip(&az_out)
                    .map(|(&x, &c)| x as f64 * c as f64)
                    .sum()
            };
            let eps = 1e-3;
            for j in 0..z.len() {
                let mut zp = z.clone();
                zp[j] += eps as f32;
                let mut zm = z.clone();
                zm[j] -= eps as f32;
                let fd = (scalar(&zp, &dynamics) - scalar(&zm, &dynamics)) / (2.0 * eps);
                assert!(
                    (fd - a_in.z[j] as f64).abs() < 5e-3,
                    "{name} a_z[{j}]: {fd} vs {}",
                    a_in.z[j]
                );
            }
            let theta0 = dynamics.params().to_vec();
            for &k in &[0usize, theta0.len() / 2, theta0.len() - 1] {
                let mut tp = theta0.clone();
                tp[k] += eps as f32;
                dynamics.set_params(&tp);
                let fp = scalar(&z, &dynamics);
                let mut tm = theta0.clone();
                tm[k] -= eps as f32;
                dynamics.set_params(&tm);
                let fm = scalar(&z, &dynamics);
                dynamics.set_params(&theta0);
                let fd = (fp - fm) / (2.0 * eps);
                assert!(
                    (fd - a_th[k] as f64).abs() < 5e-3,
                    "{name} a_θ[{k}]: {fd} vs {}",
                    a_th[k]
                );
            }
        }
    }
}
