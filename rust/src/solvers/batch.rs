//! Batch-first state containers: a mini-batch of independent trajectories
//! stored as one row-major `[B, N_z]` buffer (reusing [`Tensor`]).
//!
//! The paper's headline results are all mini-batch training runs, and the
//! exact-gradient methods (ACA, MALI) only pay off when the per-step
//! overhead is amortized across a batch (cf. Matsubara et al., 2021), so
//! the whole numeric stack — [`crate::solvers::dynamics::Dynamics`],
//! [`crate::solvers::Solver`], `integrate_batch`, the four `GradMethod`s —
//! speaks this layout natively.  Row `b` of a [`BatchState`] is one
//! sample's trajectory; all per-row arithmetic is bit-identical to the
//! single-sample path, which is what the `tests/batch_equivalence.rs`
//! suite pins down.
//!
//! MALI's Table-1 memory law `N_z(N_f + 1)` carries over with
//! `N_z → B·N_z`: the retained end state is the flat `[B·N_z]` buffer and
//! is tracked through the same `MemTracker` plumbing.

use super::State;
use crate::tensor::Tensor;

/// Shape of a batch of flattened states: `batch` rows of `n_z` features,
/// row-major in one `[B·N_z]` buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchSpec {
    /// Number of independent samples B.
    pub batch: usize,
    /// Flattened per-sample state dimension N_z.
    pub n_z: usize,
}

impl BatchSpec {
    /// A `[batch, n_z]` spec; both dimensions must be non-zero.
    pub fn new(batch: usize, n_z: usize) -> BatchSpec {
        assert!(batch > 0 && n_z > 0, "BatchSpec dims must be positive: [{batch}, {n_z}]");
        BatchSpec { batch, n_z }
    }

    /// The single-sample spec `[1, n_z]`.
    pub fn single(n_z: usize) -> BatchSpec {
        BatchSpec::new(1, n_z)
    }

    /// Total flattened length `B·N_z`.
    pub fn flat_len(&self) -> usize {
        self.batch * self.n_z
    }

    /// A spec with the same row width but `k` rows (gathered sub-batches).
    pub fn with_batch(&self, k: usize) -> BatchSpec {
        BatchSpec::new(k, self.n_z)
    }

    /// Row `b` of a flat `[B, n_z]` buffer.
    pub fn row<'a>(&self, buf: &'a [f32], b: usize) -> &'a [f32] {
        &buf[b * self.n_z..(b + 1) * self.n_z]
    }

    /// Mutable row `b` of a flat `[B, n_z]` buffer.
    pub fn row_mut<'a>(&self, buf: &'a mut [f32], b: usize) -> &'a mut [f32] {
        &mut buf[b * self.n_z..(b + 1) * self.n_z]
    }

    /// Copy rows `idxs` into a compact `[idxs.len(), n_z]` buffer.
    pub fn gather(&self, buf: &[f32], idxs: &[usize]) -> Vec<f32> {
        let mut out = Vec::with_capacity(idxs.len() * self.n_z);
        for &b in idxs {
            out.extend_from_slice(self.row(buf, b));
        }
        out
    }

    /// Scatter a compact `[idxs.len(), n_z]` buffer back into rows `idxs`.
    pub fn scatter(&self, sub: &[f32], idxs: &[usize], buf: &mut [f32]) {
        debug_assert_eq!(sub.len(), idxs.len() * self.n_z);
        for (k, &b) in idxs.iter().enumerate() {
            self.row_mut(buf, b)
                .copy_from_slice(&sub[k * self.n_z..(k + 1) * self.n_z]);
        }
    }
}

/// Solver state for a batch of trajectories: `z` (and ALF's auxiliary `v`)
/// as `[B, N_z]` tensors.  The flat `.data` buffers are what the solvers'
/// stage arithmetic (`tensor::axpy`/`lincomb`) runs over.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchState {
    /// The ODE states, shape `[B, N_z]`.
    pub z: Tensor,
    /// ALF's auxiliary velocity rows (`None` for plain RK states).
    pub v: Option<Tensor>,
}

impl BatchState {
    /// Wrap a flat `[B·N_z]` buffer (no `v`).
    pub fn from_flat(z: Vec<f32>, spec: BatchSpec) -> BatchState {
        BatchState {
            z: Tensor::new(z, vec![spec.batch, spec.n_z]),
            v: None,
        }
    }

    /// Wrap flat `z` and `v` buffers (the augmented ALF layout).
    pub fn from_flat_zv(z: Vec<f32>, v: Vec<f32>, spec: BatchSpec) -> BatchState {
        BatchState {
            z: Tensor::new(z, vec![spec.batch, spec.n_z]),
            v: Some(Tensor::new(v, vec![spec.batch, spec.n_z])),
        }
    }

    /// The `[B, N_z]` shape of this state.
    pub fn spec(&self) -> BatchSpec {
        debug_assert_eq!(self.z.shape.len(), 2);
        BatchSpec::new(self.z.shape[0], self.z.shape[1])
    }

    /// Stack single-sample states (all the same layout) into a batch.
    pub fn from_states(states: &[&State]) -> BatchState {
        assert!(!states.is_empty(), "cannot batch zero states");
        let n_z = states[0].z.len();
        let has_v = states[0].v.is_some();
        let spec = BatchSpec::new(states.len(), n_z);
        let mut z = Vec::with_capacity(spec.flat_len());
        let mut v = if has_v { Vec::with_capacity(spec.flat_len()) } else { Vec::new() };
        for s in states {
            assert_eq!(s.z.len(), n_z, "ragged state rows");
            assert_eq!(s.v.is_some(), has_v, "mixed augmented/plain states");
            z.extend_from_slice(&s.z);
            if let Some(sv) = &s.v {
                v.extend_from_slice(sv);
            }
        }
        if has_v {
            BatchState::from_flat_zv(z, v, spec)
        } else {
            BatchState::from_flat(z, spec)
        }
    }

    /// Copy of row `b` as a single-sample [`State`].
    pub fn row_state(&self, b: usize) -> State {
        let spec = self.spec();
        State {
            z: spec.row(&self.z.data, b).to_vec(),
            v: self.v.as_ref().map(|v| spec.row(&v.data, b).to_vec()),
        }
    }

    /// Logical size in bytes of one row (for per-sample MemTracker
    /// accounting, matching `State::bytes` of the solo path).
    pub fn row_bytes(&self) -> usize {
        self.spec().n_z * 4 * if self.v.is_some() { 2 } else { 1 }
    }

    /// Logical size in bytes of the whole batch.
    pub fn bytes(&self) -> usize {
        self.row_bytes() * self.spec().batch
    }

    /// Zero cotangent of the same shape.
    pub fn zeros_like(&self) -> BatchState {
        BatchState {
            z: Tensor::zeros(&self.z.shape),
            v: self.v.as_ref().map(|v| Tensor::zeros(&v.shape)),
        }
    }

    /// Compact copy of rows `idxs` (a `[idxs.len(), N_z]` batch).
    pub fn gather_rows(&self, idxs: &[usize]) -> BatchState {
        let spec = self.spec();
        let sub = spec.with_batch(idxs.len());
        let z = spec.gather(&self.z.data, idxs);
        match &self.v {
            Some(v) => BatchState::from_flat_zv(z, spec.gather(&v.data, idxs), sub),
            None => BatchState::from_flat(z, sub),
        }
    }

    /// Scatter a compact sub-batch (as produced by
    /// [`BatchState::gather_rows`]) back into rows `idxs`.
    pub fn scatter_rows(&mut self, sub: &BatchState, idxs: &[usize]) {
        let spec = self.spec();
        debug_assert_eq!(sub.spec().n_z, spec.n_z);
        debug_assert_eq!(sub.spec().batch, idxs.len());
        spec.scatter(&sub.z.data, idxs, &mut self.z.data);
        if let (Some(v), Some(sv)) = (&mut self.v, &sub.v) {
            spec.scatter(&sv.data, idxs, &mut v.data);
        }
    }

    /// Copy row `b`'s `z` (and, when present and requested, `v`) into
    /// caller-owned slices — the response-export primitive of the serving
    /// worker, which scatters a finished batch back to per-request
    /// buffers without materializing intermediate [`State`]s.
    pub fn copy_row_into(&self, b: usize, z_dst: &mut [f32], v_dst: Option<&mut [f32]>) {
        let spec = self.spec();
        z_dst.copy_from_slice(spec.row(&self.z.data, b));
        if let (Some(v), Some(dst)) = (&self.v, v_dst) {
            dst.copy_from_slice(spec.row(&v.data, b));
        }
    }

    /// Copy row `src_row` of `src` into row `dst` of `self`.
    pub fn copy_row_from(&mut self, dst: usize, src: &BatchState, src_row: usize) {
        let spec = self.spec();
        let src_spec = src.spec();
        debug_assert_eq!(spec.n_z, src_spec.n_z);
        spec.row_mut(&mut self.z.data, dst)
            .copy_from_slice(src_spec.row(&src.z.data, src_row));
        if let (Some(v), Some(sv)) = (&mut self.v, &src.v) {
            spec.row_mut(&mut v.data, dst)
                .copy_from_slice(src_spec.row(&sv.data, src_row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_rows_and_gather_scatter() {
        let spec = BatchSpec::new(3, 2);
        assert_eq!(spec.flat_len(), 6);
        let buf: Vec<f32> = vec![0.0, 1.0, 10.0, 11.0, 20.0, 21.0];
        assert_eq!(spec.row(&buf, 1), &[10.0, 11.0]);
        let sub = spec.gather(&buf, &[2, 0]);
        assert_eq!(sub, vec![20.0, 21.0, 0.0, 1.0]);
        let mut out = buf.clone();
        spec.scatter(&[5.0, 6.0, 7.0, 8.0], &[2, 0], &mut out);
        assert_eq!(out, vec![7.0, 8.0, 10.0, 11.0, 5.0, 6.0]);
    }

    #[test]
    fn state_roundtrip_through_rows() {
        let a = State {
            z: vec![1.0, 2.0],
            v: Some(vec![3.0, 4.0]),
        };
        let b = State {
            z: vec![5.0, 6.0],
            v: Some(vec![7.0, 8.0]),
        };
        let batch = BatchState::from_states(&[&a, &b]);
        assert_eq!(batch.spec(), BatchSpec::new(2, 2));
        assert_eq!(batch.row_state(0), a);
        assert_eq!(batch.row_state(1), b);
        assert_eq!(batch.bytes(), 2 * 2 * 4 * 2);
        assert_eq!(batch.row_bytes(), 16);
    }

    #[test]
    fn gather_scatter_rows_roundtrip() {
        let spec = BatchSpec::new(4, 3);
        let z: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..12).map(|i| 100.0 + i as f32).collect();
        let mut batch = BatchState::from_flat_zv(z, v, spec);
        let sub = batch.gather_rows(&[1, 3]);
        assert_eq!(sub.z.data, vec![3.0, 4.0, 5.0, 9.0, 10.0, 11.0]);
        let mut sub2 = sub.clone();
        for x in sub2.z.data.iter_mut() {
            *x = -*x;
        }
        batch.scatter_rows(&sub2, &[1, 3]);
        assert_eq!(batch.row_state(1).z, vec![-3.0, -4.0, -5.0]);
        assert_eq!(batch.row_state(3).z, vec![-9.0, -10.0, -11.0]);
        // untouched row
        assert_eq!(batch.row_state(0).z, vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn copy_row_from_moves_both_buffers() {
        let spec = BatchSpec::new(2, 2);
        let mut dst = BatchState::from_flat_zv(vec![0.0; 4], vec![0.0; 4], spec);
        let src = BatchState::from_flat_zv(
            vec![1.0, 2.0, 3.0, 4.0],
            vec![5.0, 6.0, 7.0, 8.0],
            spec,
        );
        dst.copy_row_from(0, &src, 1);
        assert_eq!(dst.row_state(0).z, vec![3.0, 4.0]);
        assert_eq!(dst.row_state(0).v.unwrap(), vec![7.0, 8.0]);
    }

    #[test]
    #[should_panic]
    fn zero_batch_rejected() {
        BatchSpec::new(0, 4);
    }
}
