//! Preallocated workspaces for the solver/grad hot paths.
//!
//! MALI's pitch is gradient estimation at constant memory and
//! near-hardware speed, yet the original inner loops allocated a fresh
//! `Vec<f32>` per stage evaluation, so steps/sec was bounded by the
//! allocator rather than the FLOPs.  A [`SolverWorkspace`] (and its
//! batched sibling [`BatchWorkspace`]) owns every buffer those loops
//! need — stage scratch, ψ/ψ⁻¹ intermediates, error vectors, the
//! recyclable state buffers the integration loops ping-pong between,
//! and (batched) the per-sample step-size-controller vectors of the
//! multi-observation loop
//! ([`integrate_batch_obs_stats_ws`](crate::solvers::integrate::integrate_batch_obs_stats_ws))
//! — so that after warm-up one accepted step performs **zero** heap
//! allocations (asserted by `tests/alloc_steady.rs` with a counting
//! global allocator), and the online serving loop re-solves whole warmed
//! batches without touching the allocator at all
//! (`tests/alloc_serve.rs`; see [`crate::serve`]).
//!
//! # Workspace contract
//!
//! * **Ownership** — the workspace owns scratch; callers own their
//!   inputs and outputs.  `_into` methods never stash references.
//! * **Aliasing** — an `_into` output buffer must not alias any input
//!   slice of the same call (the borrow checker enforces this for the
//!   slice arguments; the named scratch fields are disjoint by
//!   construction).
//! * **Warm-up** — buffers grow (or shrink) to the requested size on
//!   first use and are reused verbatim afterwards; steady-state calls
//!   with stable shapes never touch the allocator.  A workspace may be
//!   reused across calls and across solvers; shapes are re-checked per
//!   call.
//! * **Wrappers allocate** — the pre-existing allocating signatures
//!   (`psi`, `step`, `integrate`, …) remain available as thin wrappers
//!   that build the output buffers (and a transient workspace) per call,
//!   then delegate to the `_into` path, so both paths are bit-identical
//!   by construction (pinned by `tests/prop_solver.rs`).

use super::batch::BatchState;
use super::State;
use crate::tensor::Tensor;

/// Grow-once resize: reallocate only when the requested length changes.
/// Fresh elements are zeroed; existing contents are preserved when the
/// length already matches (steady state — no allocator traffic).
pub(crate) fn ensure(buf: &mut Vec<f32>, n: usize) {
    if buf.len() != n {
        buf.clear();
        buf.resize(n, 0.0);
    }
}

/// [`ensure`] for `f64` scratch (per-row times / coefficients).
pub(crate) fn ensure_f64(buf: &mut Vec<f64>, n: usize) {
    if buf.len() != n {
        buf.clear();
        buf.resize(n, 0.0);
    }
}

/// Grow-once resize for arbitrary `Clone` scratch (per-sample controller
/// state: trial counts, barrier flags, …).  Same steady-state guarantee as
/// [`ensure`]: a call with an unchanged length never touches the
/// allocator.
pub(crate) fn ensure_with<T: Clone>(buf: &mut Vec<T>, n: usize, fill: T) {
    if buf.len() != n {
        buf.clear();
        buf.resize(n, fill);
    }
}

/// Per-row `(h_b · coeff) as f32` scale vector — the same cast order as
/// the solo `(h * coeff) as f32` stage arithmetic.  The batch≡solo
/// bitwise-equivalence tests depend on this exact cast order; ALF and RK
/// share this single copy so the two solver families cannot drift.
pub(crate) fn fill_row_coeffs(hs: &[f64], coeff: f64, out: &mut Vec<f32>) {
    ensure(out, hs.len());
    for (o, &h) in out.iter_mut().zip(hs) {
        *o = (h * coeff) as f32;
    }
}

/// Per-row stage times `t_b + h_b·offset` (f64) into `out`.
pub(crate) fn fill_stage_times(ts: &[f64], hs: &[f64], offset: f64, out: &mut Vec<f64>) {
    ensure_f64(out, ts.len());
    for ((o, &t), &h) in out.iter_mut().zip(ts).zip(hs) {
        *o = t + h * offset;
    }
}

/// Shape a vec-of-stage-buffers to `stages` buffers of `n` elements.
pub(crate) fn ensure_stages(bufs: &mut Vec<Vec<f32>>, stages: usize, n: usize) {
    while bufs.len() < stages {
        bufs.push(Vec::new());
    }
    for b in bufs.iter_mut().take(stages) {
        ensure(b, n);
    }
}

/// Shape `dst` as an `n`-element state with or without a `v` buffer,
/// reusing its allocations (no allocator traffic once capacities
/// suffice).
pub(crate) fn shape_state_n(dst: &mut State, n: usize, has_v: bool) {
    ensure(&mut dst.z, n);
    if has_v {
        let dv = dst.v.get_or_insert_with(Vec::new);
        ensure(dv, n);
    } else {
        dst.v = None;
    }
}

/// Shape `dst` like `template` (same `z` length, same `v` presence).
fn shape_state(dst: &mut State, template: &State) {
    shape_state_n(dst, template.z.len(), template.v.is_some());
}

fn copy_state(dst: &mut State, src: &State) {
    dst.z.copy_from_slice(&src.z);
    if let (Some(dv), Some(sv)) = (&mut dst.v, &src.v) {
        dv.copy_from_slice(sv);
    }
}

/// Preallocated scratch + recyclable buffers for the single-sample
/// solver/grad hot paths.  See the module docs for the contract.
#[derive(Debug)]
pub struct SolverWorkspace {
    // ---- named ψ/ψ⁻¹/ψ-vjp scratch (ALF) --------------------------------
    pub(crate) k1: Vec<f32>,
    pub(crate) u1: Vec<f32>,
    pub(crate) av_tot: Vec<f32>,
    pub(crate) a_u1: Vec<f32>,
    pub(crate) g: Vec<f32>,
    /// Read-only zero cotangent (never written after `ensure`).
    pub(crate) zero: Vec<f32>,
    // ---- RK per-stage buffers -------------------------------------------
    pub(crate) ks: Vec<Vec<f32>>,
    pub(crate) ys: Vec<Vec<f32>>,
    pub(crate) a_k: Vec<Vec<f32>>,
    // ---- recyclable integration-loop buffers ----------------------------
    states: Vec<State>,
    errs: Vec<Vec<f32>>,
    /// Final state of the last `integrate*_ws` run.
    out: State,
}

impl SolverWorkspace {
    /// An empty workspace; every buffer grows on first use.
    pub fn new() -> SolverWorkspace {
        SolverWorkspace {
            k1: Vec::new(),
            u1: Vec::new(),
            av_tot: Vec::new(),
            a_u1: Vec::new(),
            g: Vec::new(),
            zero: Vec::new(),
            ks: Vec::new(),
            ys: Vec::new(),
            a_k: Vec::new(),
            states: Vec::new(),
            errs: Vec::new(),
            out: State {
                z: Vec::new(),
                v: None,
            },
        }
    }

    /// Final state left behind by the last `integrate*_ws` run.
    pub fn output(&self) -> &State {
        &self.out
    }

    /// Move the final state out of the workspace (the buffer is replaced
    /// by an empty one; the next run re-shapes it).
    pub fn take_output(&mut self) -> State {
        std::mem::replace(
            &mut self.out,
            State {
                z: Vec::new(),
                v: None,
            },
        )
    }

    /// Borrow a recycled state buffer shaped like `template` (contents
    /// unspecified).
    pub(crate) fn take_state(&mut self, template: &State) -> State {
        let mut s = self.states.pop().unwrap_or_else(|| State {
            z: Vec::new(),
            v: None,
        });
        shape_state(&mut s, template);
        s
    }

    /// Borrow a recycled state buffer holding a copy of `template`.
    pub(crate) fn take_state_copy(&mut self, template: &State) -> State {
        let mut s = self.take_state(template);
        copy_state(&mut s, template);
        s
    }

    /// Return a state buffer to the pool.
    pub(crate) fn put_state(&mut self, s: State) {
        self.states.push(s);
    }

    /// Store `s` as the run output, recycling the previous output buffer.
    pub(crate) fn set_output(&mut self, s: State) {
        let prev = std::mem::replace(&mut self.out, s);
        self.put_state(prev);
    }

    /// Borrow a recycled flat buffer (length unspecified; callers
    /// `ensure` it).
    pub(crate) fn take_err(&mut self) -> Vec<f32> {
        self.errs.pop().unwrap_or_default()
    }

    /// Return a flat buffer to the pool.
    pub(crate) fn put_err(&mut self, e: Vec<f32>) {
        self.errs.push(e);
    }
}

impl Default for SolverWorkspace {
    fn default() -> Self {
        SolverWorkspace::new()
    }
}

/// Shape `dst` as a `[batch, n_z]` batch state with (or without) a `v`
/// buffer, reusing its allocations.
pub(crate) fn shape_batch_state(dst: &mut BatchState, batch: usize, n_z: usize, has_v: bool) {
    ensure(&mut dst.z.data, batch * n_z);
    dst.z.shape.clear();
    dst.z.shape.extend_from_slice(&[batch, n_z]);
    if has_v {
        if dst.v.is_none() {
            dst.v = Some(Tensor {
                data: Vec::new(),
                shape: Vec::new(),
            });
        }
        let v = dst.v.as_mut().expect("just ensured");
        ensure(&mut v.data, batch * n_z);
        v.shape.clear();
        v.shape.extend_from_slice(&[batch, n_z]);
    } else {
        dst.v = None;
    }
}

/// Preallocated scratch + recyclable buffers for the batched (`[B, N_z]`)
/// solver/grad/serve hot paths — the flat-buffer mirror of
/// [`SolverWorkspace`], extended with the per-sample controller scratch
/// the batched integration loop needs (one warm workspace per serving
/// worker is what keeps the steady-state serve loop allocation-free).
#[derive(Debug)]
pub struct BatchWorkspace {
    // ---- named ψ/ψ⁻¹/ψ-vjp scratch (ALF, flat `[B·N_z]`) ----------------
    pub(crate) k1: Vec<f32>,
    pub(crate) u1: Vec<f32>,
    pub(crate) av_tot: Vec<f32>,
    pub(crate) a_u1: Vec<f32>,
    pub(crate) g: Vec<f32>,
    pub(crate) zero: Vec<f32>,
    // ---- per-row coefficient / time scratch -----------------------------
    pub(crate) half: Vec<f32>,
    pub(crate) coeffs: Vec<f32>,
    pub(crate) s1s: Vec<f64>,
    pub(crate) ts_in: Vec<f64>,
    // ---- reversible-4 per-row sub-step scratch --------------------------
    //
    // The composed solver re-parameterizes each row's `(t, h)` into three
    // ALF sub-steps; these hold the per-row sub-step times/sizes and cross
    // the `&mut ws` boundary via the usual take/restore rule.
    pub(crate) sub_ts: Vec<f64>,
    pub(crate) sub_hs: Vec<f64>,
    // ---- batched-loop per-sample controller scratch ---------------------
    //
    // The `integrate_batch_obs_stats_ws` loop keeps one step-size
    // controller per sample; these vectors hold that per-row state so a
    // warmed serve/grad loop re-runs the whole batched solve without
    // touching the allocator.  They are `mem::take`n out of the workspace
    // for the duration of a run (the loop passes `&mut ws` to the solver)
    // and restored on the way out — the same crossing rule as `ts_in`.
    pub(crate) ts_row: Vec<f64>,
    pub(crate) hs_row: Vec<f64>,
    pub(crate) t_cur: Vec<f64>,
    pub(crate) h_cur: Vec<f64>,
    pub(crate) h_free: Vec<f64>,
    pub(crate) trials_cur: Vec<usize>,
    pub(crate) accepted_idx: Vec<usize>,
    pub(crate) next_obs_row: Vec<usize>,
    pub(crate) aimed: Vec<bool>,
    pub(crate) active: Vec<usize>,
    pub(crate) still: Vec<usize>,
    // ---- RK per-stage buffers (flat `[B·N_z]` each) ---------------------
    pub(crate) ks: Vec<Vec<f32>>,
    pub(crate) ys: Vec<Vec<f32>>,
    pub(crate) a_k: Vec<Vec<f32>>,
    // ---- recyclable integration-loop buffers ----------------------------
    states: Vec<BatchState>,
    errs: Vec<Vec<f32>>,
    out: BatchState,
}

fn empty_batch_state() -> BatchState {
    BatchState {
        z: Tensor {
            data: Vec::new(),
            shape: vec![0, 0],
        },
        v: None,
    }
}

impl BatchWorkspace {
    /// An empty workspace; every buffer grows on first use.
    pub fn new() -> BatchWorkspace {
        BatchWorkspace {
            k1: Vec::new(),
            u1: Vec::new(),
            av_tot: Vec::new(),
            a_u1: Vec::new(),
            g: Vec::new(),
            zero: Vec::new(),
            half: Vec::new(),
            coeffs: Vec::new(),
            s1s: Vec::new(),
            ts_in: Vec::new(),
            sub_ts: Vec::new(),
            sub_hs: Vec::new(),
            ts_row: Vec::new(),
            hs_row: Vec::new(),
            t_cur: Vec::new(),
            h_cur: Vec::new(),
            h_free: Vec::new(),
            trials_cur: Vec::new(),
            accepted_idx: Vec::new(),
            next_obs_row: Vec::new(),
            aimed: Vec::new(),
            active: Vec::new(),
            still: Vec::new(),
            ks: Vec::new(),
            ys: Vec::new(),
            a_k: Vec::new(),
            states: Vec::new(),
            errs: Vec::new(),
            out: empty_batch_state(),
        }
    }

    /// Final batch state left behind by the last `integrate_batch*_ws` run.
    pub fn output(&self) -> &BatchState {
        &self.out
    }

    /// Move the final batch state out of the workspace.
    pub fn take_output(&mut self) -> BatchState {
        std::mem::replace(&mut self.out, empty_batch_state())
    }

    /// Borrow a recycled `[batch, n_z]` batch-state buffer (contents
    /// unspecified).
    pub(crate) fn take_batch(&mut self, batch: usize, n_z: usize, has_v: bool) -> BatchState {
        let mut s = self.states.pop().unwrap_or_else(empty_batch_state);
        shape_batch_state(&mut s, batch, n_z, has_v);
        s
    }

    /// Borrow a recycled batch-state buffer holding a copy of `template`.
    pub(crate) fn take_batch_copy(&mut self, template: &BatchState) -> BatchState {
        let spec = template.spec();
        let mut s = self.take_batch(spec.batch, spec.n_z, template.v.is_some());
        s.z.data.copy_from_slice(&template.z.data);
        if let (Some(dv), Some(sv)) = (&mut s.v, &template.v) {
            dv.data.copy_from_slice(&sv.data);
        }
        s
    }

    /// Return a batch-state buffer to the pool.
    pub(crate) fn put_batch(&mut self, s: BatchState) {
        self.states.push(s);
    }

    /// Store `s` as the run output, recycling the previous output buffer.
    pub(crate) fn set_output(&mut self, s: BatchState) {
        let prev = std::mem::replace(&mut self.out, s);
        self.put_batch(prev);
    }

    /// Borrow a recycled flat buffer (length unspecified).
    pub(crate) fn take_err(&mut self) -> Vec<f32> {
        self.errs.pop().unwrap_or_default()
    }

    /// Return a flat buffer to the pool.
    pub(crate) fn put_err(&mut self, e: Vec<f32>) {
        self.errs.push(e);
    }
}

impl Default for BatchWorkspace {
    fn default() -> Self {
        BatchWorkspace::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_grows_and_reuses() {
        let mut b = Vec::new();
        ensure(&mut b, 4);
        assert_eq!(b, vec![0.0f32; 4]);
        b[0] = 7.0;
        let ptr = b.as_ptr();
        ensure(&mut b, 4);
        assert_eq!(b[0], 7.0, "same-size ensure preserves contents");
        assert_eq!(b.as_ptr(), ptr, "same-size ensure does not reallocate");
        ensure(&mut b, 2);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn state_pool_shapes_and_recycles() {
        let mut ws = SolverWorkspace::new();
        let template = State {
            z: vec![1.0, 2.0],
            v: Some(vec![3.0, 4.0]),
        };
        let s = ws.take_state_copy(&template);
        assert_eq!(s, template);
        ws.put_state(s);
        // re-take with a v-less template: v buffer is dropped
        let plain = State {
            z: vec![5.0, 6.0, 7.0],
            v: None,
        };
        let s = ws.take_state_copy(&plain);
        assert_eq!(s, plain);
        ws.put_state(s);
    }

    #[test]
    fn batch_pool_shapes_and_recycles() {
        let mut ws = BatchWorkspace::new();
        let spec = crate::solvers::batch::BatchSpec::new(2, 3);
        let template = BatchState::from_flat_zv(
            (0..6).map(|i| i as f32).collect(),
            (0..6).map(|i| 10.0 + i as f32).collect(),
            spec,
        );
        let s = ws.take_batch_copy(&template);
        assert_eq!(s, template);
        assert_eq!(s.spec(), spec);
        ws.put_batch(s);
        let s = ws.take_batch(3, 2, false);
        assert_eq!(s.spec(), crate::solvers::batch::BatchSpec::new(3, 2));
        assert!(s.v.is_none());
    }

    #[test]
    fn output_slot_roundtrip() {
        let mut ws = SolverWorkspace::new();
        ws.set_output(State {
            z: vec![1.0],
            v: None,
        });
        assert_eq!(ws.output().z, vec![1.0]);
        let s = ws.take_output();
        assert_eq!(s.z, vec![1.0]);
        assert!(ws.output().z.is_empty());
    }
}
