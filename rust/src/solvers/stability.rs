//! A-stability analysis of the damped ALF integrator (paper Theorem 3.2,
//! Appendix A.4/A.5 and Appendix Fig. 1).
//!
//! For Jacobian eigenvalue σ and step h, the one-step amplification factors
//! of damped ALF are
//!
//! ```text
//! λ±(w) = 1 + η(w − 1) ± sqrt( η·[2w + η(w − 1)²] ),    w = hσ ∈ ℂ
//! ```
//!
//! The step is stable at `w` iff max(|λ₊|, |λ₋|) < 1.  At η = 1 the stable
//! region is empty (boundary only on the imaginary segment [−i, i]); for
//! η < 1 a non-empty region opens in the left half plane.

/// Minimal complex arithmetic (no external crates offline).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    pub fn new(re: f64, im: f64) -> C64 {
        C64 { re, im }
    }

    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }

    pub fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }

    pub fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }

    pub fn mul(self, o: C64) -> C64 {
        C64::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }

    pub fn scale(self, s: f64) -> C64 {
        C64::new(self.re * s, self.im * s)
    }

    /// Principal square root.
    pub fn sqrt(self) -> C64 {
        let r = self.abs();
        let re = ((r + self.re) / 2.0).max(0.0).sqrt();
        let im_mag = ((r - self.re) / 2.0).max(0.0).sqrt();
        C64::new(re, if self.im >= 0.0 { im_mag } else { -im_mag })
    }
}

/// Amplification factors λ± of one damped-ALF step at `w = hσ`.
pub fn alf_amplification(w: C64, eta: f64) -> (C64, C64) {
    // base = 1 + η(w − 1)
    let base = C64::new(1.0 - eta, 0.0).add(w.scale(eta));
    // disc = η·(2w + η(w−1)²)
    let wm1 = w.sub(C64::new(1.0, 0.0));
    let disc = w.scale(2.0).add(wm1.mul(wm1).scale(eta)).scale(eta);
    let root = disc.sqrt();
    (base.add(root), base.sub(root))
}

/// True iff damped ALF is stable at `w = hσ`.
pub fn is_stable(w: C64, eta: f64) -> bool {
    let (lp, lm) = alf_amplification(w, eta);
    lp.abs() < 1.0 && lm.abs() < 1.0
}

/// Stability-region scan over `[re_lo, re_hi] × [im_lo, im_hi]` with an
/// `n × n` grid.  Returns `(area, mask)` where `mask[i*n+j]` marks stable
/// grid cells — the data behind Appendix Fig. 1.
pub fn stability_region(
    eta: f64,
    re_lo: f64,
    re_hi: f64,
    im_lo: f64,
    im_hi: f64,
    n: usize,
) -> (f64, Vec<bool>) {
    let mut mask = vec![false; n * n];
    let cell = ((re_hi - re_lo) / n as f64) * ((im_hi - im_lo) / n as f64);
    let mut count = 0usize;
    for i in 0..n {
        let im = im_lo + (im_hi - im_lo) * (i as f64 + 0.5) / n as f64;
        for j in 0..n {
            let re = re_lo + (re_hi - re_lo) * (j as f64 + 0.5) / n as f64;
            if is_stable(C64::new(re, im), eta) {
                mask[i * n + j] = true;
                count += 1;
            }
        }
    }
    (count as f64 * cell, mask)
}

/// Render the region mask as an ASCII plot (rows = imaginary axis).
pub fn ascii_region(mask: &[bool], n: usize) -> String {
    let mut out = String::new();
    for i in (0..n).rev() {
        for j in 0..n {
            out.push(if mask[i * n + j] { '#' } else { '.' });
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::alf::AlfSolver;
    use crate::solvers::dynamics::{ComplexEigenDynamics, Dynamics};

    #[test]
    fn complex_sqrt_identity() {
        for &(re, im) in &[(3.0, 4.0), (-1.0, 2.0), (0.0, -5.0), (2.5, 0.0)] {
            let w = C64::new(re, im);
            let r = w.sqrt();
            let back = r.mul(r);
            assert!((back.re - re).abs() < 1e-10 && (back.im - im).abs() < 1e-10);
        }
    }

    /// Theorem A.2: undamped ALF (η = 1) is nowhere strictly A-stable.
    #[test]
    fn eta_one_region_empty() {
        let (area, _) = stability_region(1.0, -3.0, 0.5, -2.0, 2.0, 120);
        assert_eq!(area, 0.0);
    }

    /// η < 1 opens a non-empty region, and the area shrinks as η → 1
    /// (Appendix Fig. 1: η 0.25 > 0.7 > 0.8).
    #[test]
    fn area_decreases_with_eta() {
        let area = |eta: f64| stability_region(eta, -3.0, 0.5, -2.0, 2.0, 120).0;
        let (a25, a70, a80) = (area(0.25), area(0.7), area(0.8));
        assert!(a25 > a70, "{a25} vs {a70}");
        assert!(a70 > a80, "{a70} vs {a80}");
        assert!(a80 > 0.0);
    }

    /// At η = 1 and w = hσ purely imaginary with |w| ≤ 1, the amplification
    /// sits on the critical boundary |λ| = 1 (Theorem A.2).
    #[test]
    fn eta_one_imaginary_axis_critical() {
        for &y in &[0.1, 0.5, 0.9] {
            let (lp, lm) = alf_amplification(C64::new(0.0, y), 1.0);
            assert!((lp.abs() - 1.0).abs() < 1e-9, "{}", lp.abs());
            assert!((lm.abs() - 1.0).abs() < 1e-9);
        }
    }

    /// Empirical cross-check: integrating dz/dt = σz with damped ALF decays
    /// when the theorem says stable and blows up when it says unstable.
    #[test]
    fn predicted_stability_matches_integration() {
        let eta = 0.7;
        let h = 1.0;
        let cases = [(-0.8f64, 0.3f64), (-2.5, 0.0), (0.3, 0.5)];
        for &(re, im) in &cases {
            let w = C64::new(re * h, im * h);
            let predicted = is_stable(w, eta);
            let dynamics = ComplexEigenDynamics::new(vec![(re as f32, im as f32)]);
            let solver = AlfSolver::new(eta);
            let mut z = vec![1.0f32, 0.0];
            let mut v = dynamics.f(0.0, &z);
            let mut t = 0.0;
            for _ in 0..200 {
                let (z1, v1, _) = solver.psi(&dynamics, t, h, &z, &v);
                z = z1;
                v = v1;
                t += h;
                if z[0].abs() > 1e20 {
                    break;
                }
            }
            let norm = (z[0] as f64).hypot(z[1] as f64);
            if predicted {
                assert!(norm < 10.0, "σ={re}+{im}i predicted stable, norm {norm}");
            } else {
                assert!(norm > 10.0, "σ={re}+{im}i predicted unstable, norm {norm}");
            }
        }
    }

    #[test]
    fn ascii_render_shape() {
        let (_, mask) = stability_region(0.25, -3.0, 0.5, -2.0, 2.0, 20);
        let art = ascii_region(&mask, 20);
        assert_eq!(art.lines().count(), 20);
        assert!(art.contains('#'));
    }
}
