//! The (damped) Asynchronous Leapfrog integrator — paper Algo. 2/3 and
//! Appendix A.5 — plus its exact vjp.
//!
//! ALF advances the augmented state `(z, v)` where `v` approximates `dz/dt`:
//!
//! ```text
//! s1 = t + h/2            k1 = z + v·h/2          u1 = f(k1, s1)
//! v' = v + 2η(u1 − v)     z' = k1 + v'·h/2
//! ```
//!
//! For η = 1 this is Mutze's ALF; η ∈ (0.5, 1) is the damped variant of
//! Theorem 3.2 (η ≤ 0.5 would make the inverse singular: the `v` update has
//! factor `1 − 2η`).  The step is **algebraically invertible** for free-form
//! `f` (Algo. 3 / Eq. 49), which is what gives MALI its constant-memory
//! accurate reverse trajectory.
//!
//! Embedded error estimate: `err = η·h·(u1 − v)` — the gap between ALF's
//! update and the first-order prediction `z + h·v`; this is the `(2,1)`
//! embedded pair driving the adaptive controller (order p = 2 for step-size
//! selection), and it directly measures the `|f(z₀) − v₀|` drift term the
//! truncation analysis (Thm. 3.1 / A.3) identifies.

use super::batch::{BatchSpec, BatchState};
use super::dynamics::Dynamics;
use super::workspace::{
    ensure, fill_row_coeffs, fill_stage_times, BatchWorkspace, SolverWorkspace,
};
use super::{Solver, State};
use crate::tensor::{add_scaled_into, add_scaled_rows_into, axpy, axpy_rows};

#[derive(Debug, Clone, Copy)]
pub struct AlfSolver {
    /// Damping coefficient η ∈ (0.5, 1.0]; η = 1 is undamped ALF.
    pub eta: f64,
    /// Use the device-side fused step when the dynamics provides one.
    pub prefer_fused: bool,
}

impl AlfSolver {
    pub fn new(eta: f64) -> Self {
        assert!(
            eta > 0.5 && eta <= 1.0,
            "damped ALF requires eta in (0.5, 1]; got {eta} (inverse is singular at 0.5)"
        );
        AlfSolver {
            eta,
            prefer_fused: true,
        }
    }

    /// ψ: one (damped) ALF step composed from `f`.  Returns
    /// `(z_out, v_out, err)`.  Allocating wrapper over
    /// [`AlfSolver::psi_into`], bit-identical.
    pub fn psi(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        z: &[f32],
        v: &[f32],
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut ws = SolverWorkspace::new();
        let mut z_out = vec![0.0f32; z.len()];
        let mut v_out = vec![0.0f32; v.len()];
        let mut err = vec![0.0f32; v.len()];
        self.psi_into(dynamics, t, h, z, v, &mut z_out, &mut v_out, &mut err, &mut ws);
        (z_out, v_out, err)
    }

    /// ψ into caller buffers (`z_out`/`v_out`/`err_out`, each `z.len()`
    /// long, aliasing nothing); scratch from `ws` — zero allocations in
    /// steady state when the dynamics implements `f_into` in place.
    #[allow(clippy::too_many_arguments)]
    pub fn psi_into(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        z: &[f32],
        v: &[f32],
        z_out: &mut [f32],
        v_out: &mut [f32],
        err_out: &mut [f32],
        ws: &mut SolverWorkspace,
    ) {
        if self.prefer_fused
            && dynamics.fused_alf_into(z, v, t, h, self.eta, z_out, v_out, err_out)
        {
            return;
        }
        let eta = self.eta as f32;
        let hf = h as f32;
        let s1 = t + h / 2.0;
        let n = z.len();
        // k1 = z + v·h/2
        ensure(&mut ws.k1, n);
        add_scaled_into(z, hf / 2.0, v, &mut ws.k1);
        ensure(&mut ws.u1, n);
        dynamics.f_into(s1, &ws.k1, &mut ws.u1);
        // v' = (1-2η) v + 2η u1
        v_out.fill(0.0);
        axpy(1.0 - 2.0 * eta, v, v_out);
        axpy(2.0 * eta, &ws.u1, v_out);
        // z' = k1 + v'·h/2
        add_scaled_into(&ws.k1, hf / 2.0, v_out, z_out);
        // err = η·h·(u1 − v)
        for ((e, &u), &vi) in err_out.iter_mut().zip(&ws.u1).zip(v) {
            *e = eta * hf * (u - vi);
        }
    }

    /// ψ⁻¹: exact inverse (Algo. 3 for η = 1; Eq. 49 in general).
    /// Allocating wrapper over [`AlfSolver::psi_inv_into`], bit-identical.
    pub fn psi_inv(
        &self,
        dynamics: &dyn Dynamics,
        t_out: f64,
        h: f64,
        z_out: &[f32],
        v_out: &[f32],
    ) -> (Vec<f32>, Vec<f32>) {
        let mut ws = SolverWorkspace::new();
        let mut z_in = vec![0.0f32; z_out.len()];
        let mut v_in = vec![0.0f32; v_out.len()];
        self.psi_inv_into(dynamics, t_out, h, z_out, v_out, &mut z_in, &mut v_in, &mut ws);
        (z_in, v_in)
    }

    /// ψ⁻¹ into caller buffers; scratch from `ws`.
    #[allow(clippy::too_many_arguments)]
    pub fn psi_inv_into(
        &self,
        dynamics: &dyn Dynamics,
        t_out: f64,
        h: f64,
        z_out: &[f32],
        v_out: &[f32],
        z_in: &mut [f32],
        v_in: &mut [f32],
        ws: &mut SolverWorkspace,
    ) {
        if self.prefer_fused
            && dynamics.fused_alf_inv_into(z_out, v_out, t_out, h, self.eta, z_in, v_in)
        {
            return;
        }
        let eta = self.eta as f32;
        let hf = h as f32;
        let s1 = t_out - h / 2.0;
        let n = z_out.len();
        // k1 = z' − v'·h/2
        ensure(&mut ws.k1, n);
        add_scaled_into(z_out, -hf / 2.0, v_out, &mut ws.k1);
        ensure(&mut ws.u1, n);
        dynamics.f_into(s1, &ws.k1, &mut ws.u1);
        // v = (v' − 2η u1) / (1 − 2η)
        let denom = 1.0 - 2.0 * eta;
        for ((vi, &vo), &u) in v_in.iter_mut().zip(v_out).zip(&ws.u1) {
            *vi = (vo - 2.0 * eta * u) / denom;
        }
        // z = k1 − v·h/2
        add_scaled_into(&ws.k1, -hf / 2.0, v_in, z_in);
    }

    /// vjp through ψ: given cotangents `(a_z', a_v')` on the outputs,
    /// return `(a_z, a_v, a_θ)` on the inputs.  This is the "local backward"
    /// of MALI (Algo. 4), ACA and the naive method.  Allocating wrapper
    /// over [`AlfSolver::psi_vjp_into`], bit-identical.
    #[allow(clippy::too_many_arguments)]
    pub fn psi_vjp(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        z: &[f32],
        v: &[f32],
        az_out: &[f32],
        av_out: &[f32],
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut ws = SolverWorkspace::new();
        let mut az_in = vec![0.0f32; z.len()];
        let mut av_in = vec![0.0f32; v.len()];
        let mut a_theta = vec![0.0f32; dynamics.param_dim()];
        self.psi_vjp_into(
            dynamics,
            t,
            h,
            z,
            v,
            az_out,
            av_out,
            &mut az_in,
            &mut av_in,
            &mut a_theta,
            &mut ws,
        );
        (az_in, av_in, a_theta)
    }

    /// ψ-vjp into caller buffers; the θ-cotangent is accumulated into
    /// `ath_acc` (`+=`, matching the `axpy(1.0, ..)` the gradient loops
    /// perform on the wrapper's return value).
    #[allow(clippy::too_many_arguments)]
    pub fn psi_vjp_into(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        z: &[f32],
        v: &[f32],
        az_out: &[f32],
        av_out: &[f32],
        az_in: &mut [f32],
        av_in: &mut [f32],
        ath_acc: &mut [f32],
        ws: &mut SolverWorkspace,
    ) {
        if self.prefer_fused
            && dynamics.fused_alf_vjp_into(
                z, v, t, h, self.eta, az_out, av_out, az_in, av_in, ath_acc,
            )
        {
            return;
        }
        let eta = self.eta as f32;
        let hf = h as f32;
        let s1 = t + h / 2.0;
        let n = z.len();
        ensure(&mut ws.k1, n);
        add_scaled_into(z, hf / 2.0, v, &mut ws.k1);
        // z' = k1 + (h/2) v'  ⇒  a_k1 ← a_z',  a_v'_tot = a_v' + (h/2) a_z'
        ensure(&mut ws.av_tot, n);
        add_scaled_into(av_out, hf / 2.0, az_out, &mut ws.av_tot);
        // v' = (1−2η) v + 2η u1  ⇒  a_v += (1−2η) a_v'_tot,  a_u1 = 2η a_v'_tot
        for (o, &x) in av_in.iter_mut().zip(&ws.av_tot) {
            *o = (1.0 - 2.0 * eta) * x;
        }
        ensure(&mut ws.a_u1, n);
        for (o, &x) in ws.a_u1.iter_mut().zip(&ws.av_tot) {
            *o = 2.0 * eta * x;
        }
        // u1 = f(k1, s1)
        ensure(&mut ws.g, n);
        dynamics.f_vjp_into(s1, &ws.k1, &ws.a_u1, &mut ws.g, ath_acc);
        // a_k1 = a_z' + g_k1
        add_scaled_into(az_out, 1.0, &ws.g, az_in);
        // k1 = z + (h/2) v  ⇒  a_z = a_k1,  a_v += (h/2) a_k1
        axpy(hf / 2.0, az_in, av_in);
    }

    // ---- batched ψ / ψ⁻¹ / ψ-vjp ---------------------------------------
    //
    // Stage arithmetic runs over the flat `[B·N_z]` buffer with per-row
    // step sizes; `f` is one `f_batch` call per stage regardless of B.
    // Per-row arithmetic is identical to the single-sample methods above —
    // the batch/single roundoff-equivalence tests depend on that.

    /// Batched ψ over `[B, N_z]` rows with per-row `(t, h)`.  Allocating
    /// wrapper over [`AlfSolver::psi_batch_into`], bit-identical.
    pub fn psi_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        z: &[f32],
        v: &[f32],
        spec: &BatchSpec,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut ws = BatchWorkspace::new();
        let mut z_out = vec![0.0f32; z.len()];
        let mut v_out = vec![0.0f32; v.len()];
        let mut err = vec![0.0f32; v.len()];
        self.psi_batch_into(
            dynamics, ts, hs, z, v, spec, &mut z_out, &mut v_out, &mut err, &mut ws,
        );
        (z_out, v_out, err)
    }

    /// Batched ψ into caller buffers; scratch from `ws`.
    #[allow(clippy::too_many_arguments)]
    pub fn psi_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        z: &[f32],
        v: &[f32],
        spec: &BatchSpec,
        z_out: &mut [f32],
        v_out: &mut [f32],
        err_out: &mut [f32],
        ws: &mut BatchWorkspace,
    ) {
        if self.prefer_fused
            && dynamics.fused_alf_batch_into(ts, hs, z, v, self.eta, spec, z_out, v_out, err_out)
        {
            return;
        }
        let eta = self.eta as f32;
        let n = spec.flat_len();
        fill_row_coeffs(hs, 0.5, &mut ws.half);
        fill_stage_times(ts, hs, 0.5, &mut ws.s1s);
        ensure(&mut ws.k1, n);
        add_scaled_rows_into(z, &ws.half, v, spec.n_z, &mut ws.k1);
        ensure(&mut ws.u1, n);
        dynamics.f_batch_into(&ws.s1s, &ws.k1, spec, &mut ws.u1);
        // v' = (1-2η) v + 2η u1  (η is shared, so this stays flat)
        v_out.fill(0.0);
        axpy(1.0 - 2.0 * eta, v, v_out);
        axpy(2.0 * eta, &ws.u1, v_out);
        // z' = k1 + v'·h/2
        add_scaled_rows_into(&ws.k1, &ws.half, v_out, spec.n_z, z_out);
        // err = η·h_b·(u1 − v) per row
        for b in 0..spec.batch {
            let hf = hs[b] as f32;
            let lo = b * spec.n_z;
            let hi = lo + spec.n_z;
            for ((e, &u), &vi) in err_out[lo..hi]
                .iter_mut()
                .zip(&ws.u1[lo..hi])
                .zip(&v[lo..hi])
            {
                *e = eta * hf * (u - vi);
            }
        }
    }

    /// Batched exact ψ⁻¹ with per-row `(t_out, h)`.  Allocating wrapper
    /// over [`AlfSolver::psi_inv_batch_into`], bit-identical.
    pub fn psi_inv_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts_out: &[f64],
        hs: &[f64],
        z_out: &[f32],
        v_out: &[f32],
        spec: &BatchSpec,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut ws = BatchWorkspace::new();
        let mut z_in = vec![0.0f32; z_out.len()];
        let mut v_in = vec![0.0f32; v_out.len()];
        self.psi_inv_batch_into(
            dynamics, ts_out, hs, z_out, v_out, spec, &mut z_in, &mut v_in, &mut ws,
        );
        (z_in, v_in)
    }

    /// Batched ψ⁻¹ into caller buffers; scratch from `ws`.
    #[allow(clippy::too_many_arguments)]
    pub fn psi_inv_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        ts_out: &[f64],
        hs: &[f64],
        z_out: &[f32],
        v_out: &[f32],
        spec: &BatchSpec,
        z_in: &mut [f32],
        v_in: &mut [f32],
        ws: &mut BatchWorkspace,
    ) {
        if self.prefer_fused
            && dynamics
                .fused_alf_inv_batch_into(ts_out, hs, z_out, v_out, self.eta, spec, z_in, v_in)
        {
            return;
        }
        let eta = self.eta as f32;
        let n = spec.flat_len();
        fill_row_coeffs(hs, -0.5, &mut ws.half);
        fill_stage_times(ts_out, hs, -0.5, &mut ws.s1s);
        // k1 = z' − v'·h/2
        ensure(&mut ws.k1, n);
        add_scaled_rows_into(z_out, &ws.half, v_out, spec.n_z, &mut ws.k1);
        ensure(&mut ws.u1, n);
        dynamics.f_batch_into(&ws.s1s, &ws.k1, spec, &mut ws.u1);
        // v = (v' − 2η u1) / (1 − 2η)
        let denom = 1.0 - 2.0 * eta;
        for ((vi, &vo), &u) in v_in.iter_mut().zip(v_out).zip(&ws.u1) {
            *vi = (vo - 2.0 * eta * u) / denom;
        }
        // z = k1 − v·h/2
        add_scaled_rows_into(&ws.k1, &ws.half, v_in, spec.n_z, z_in);
    }

    /// Batched vjp through ψ; the θ-cotangent is summed over rows.
    /// Allocating wrapper over [`AlfSolver::psi_vjp_batch_into`],
    /// bit-identical.
    #[allow(clippy::too_many_arguments)]
    pub fn psi_vjp_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        z: &[f32],
        v: &[f32],
        az_out: &[f32],
        av_out: &[f32],
        spec: &BatchSpec,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut ws = BatchWorkspace::new();
        let mut az_in = vec![0.0f32; z.len()];
        let mut av_in = vec![0.0f32; v.len()];
        let mut a_theta = vec![0.0f32; dynamics.param_dim()];
        self.psi_vjp_batch_into(
            dynamics,
            ts,
            hs,
            z,
            v,
            az_out,
            av_out,
            spec,
            &mut az_in,
            &mut av_in,
            &mut a_theta,
            &mut ws,
        );
        (az_in, av_in, a_theta)
    }

    /// Batched ψ-vjp into caller buffers; the row-summed θ-cotangent is
    /// accumulated into `ath_acc`.
    #[allow(clippy::too_many_arguments)]
    pub fn psi_vjp_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        z: &[f32],
        v: &[f32],
        az_out: &[f32],
        av_out: &[f32],
        spec: &BatchSpec,
        az_in: &mut [f32],
        av_in: &mut [f32],
        ath_acc: &mut [f32],
        ws: &mut BatchWorkspace,
    ) {
        if self.prefer_fused
            && dynamics.fused_alf_vjp_batch_into(
                ts, hs, z, v, self.eta, spec, az_out, av_out, az_in, av_in, ath_acc,
            )
        {
            return;
        }
        let eta = self.eta as f32;
        let n = spec.flat_len();
        fill_row_coeffs(hs, 0.5, &mut ws.half);
        fill_stage_times(ts, hs, 0.5, &mut ws.s1s);
        ensure(&mut ws.k1, n);
        add_scaled_rows_into(z, &ws.half, v, spec.n_z, &mut ws.k1);
        // z' = k1 + (h/2) v'  ⇒  a_k1 ← a_z',  a_v'_tot = a_v' + (h/2) a_z'
        ensure(&mut ws.av_tot, n);
        add_scaled_rows_into(av_out, &ws.half, az_out, spec.n_z, &mut ws.av_tot);
        // v' = (1−2η) v + 2η u1  ⇒  a_v += (1−2η) a_v'_tot,  a_u1 = 2η a_v'_tot
        for (o, &x) in av_in.iter_mut().zip(&ws.av_tot) {
            *o = (1.0 - 2.0 * eta) * x;
        }
        ensure(&mut ws.a_u1, n);
        for (o, &x) in ws.a_u1.iter_mut().zip(&ws.av_tot) {
            *o = 2.0 * eta * x;
        }
        // u1 = f(k1, s1)
        ensure(&mut ws.g, n);
        dynamics.f_vjp_batch_into(&ws.s1s, &ws.k1, &ws.a_u1, spec, &mut ws.g, ath_acc);
        // a_k1 = a_z' + g_k1
        add_scaled_into(az_out, 1.0, &ws.g, az_in);
        // k1 = z + (h/2) v  ⇒  a_z = a_k1,  a_v += (h/2) a_k1
        axpy_rows(&ws.half, az_in, av_in, spec.n_z);
    }
}

impl Solver for AlfSolver {
    fn name(&self) -> &'static str {
        if self.eta == 1.0 {
            "alf"
        } else {
            "alf-damped"
        }
    }

    fn order(&self) -> usize {
        2
    }

    fn has_error_estimate(&self) -> bool {
        true
    }

    fn init(&self, dynamics: &dyn Dynamics, t0: f64, z0: &[f32]) -> State {
        // Paper §3.1: v₀ = f(z₀, t₀).
        let v0 = dynamics.f(t0, z0);
        State {
            z: z0.to_vec(),
            v: Some(v0),
        }
    }

    fn step(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s: &State,
    ) -> (State, Option<Vec<f32>>) {
        let v = s.v.as_ref().expect("ALF needs augmented state (z, v)");
        let (z_out, v_out, err) = self.psi(dynamics, t, h, &s.z, v);
        (
            State {
                z: z_out,
                v: Some(v_out),
            },
            Some(err),
        )
    }

    fn step_vjp(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s_in: &State,
        a_out: &State,
    ) -> (State, Vec<f32>) {
        let v = s_in.v.as_ref().expect("ALF needs augmented state");
        let zero;
        let av_out = match &a_out.v {
            Some(av) => av.as_slice(),
            None => {
                zero = vec![0.0f32; v.len()];
                &zero
            }
        };
        let (a_z, a_v, a_theta) =
            self.psi_vjp(dynamics, t, h, &s_in.z, v, &a_out.z, av_out);
        (
            State {
                z: a_z,
                v: Some(a_v),
            },
            a_theta,
        )
    }

    fn invert(
        &self,
        dynamics: &dyn Dynamics,
        t_out: f64,
        h: f64,
        s_out: &State,
    ) -> Option<State> {
        let v = s_out.v.as_ref().expect("ALF needs augmented state");
        let (z_in, v_in) = self.psi_inv(dynamics, t_out, h, &s_out.z, v);
        Some(State {
            z: z_in,
            v: Some(v_in),
        })
    }

    fn is_invertible(&self) -> bool {
        true
    }

    fn invert_and_vjp(
        &self,
        dynamics: &dyn Dynamics,
        t_out: f64,
        h: f64,
        s_out: &State,
        a_out: &State,
    ) -> Option<(State, State, Vec<f32>)> {
        if self.prefer_fused {
            let v_out = s_out.v.as_ref().expect("ALF needs augmented state");
            let zero;
            let av_out = match &a_out.v {
                Some(av) => av.as_slice(),
                None => {
                    zero = vec![0.0f32; v_out.len()];
                    &zero
                }
            };
            if let Some((z_in, v_in, a_z, a_v, a_th)) = dynamics.fused_alf_bwd(
                &s_out.z, v_out, t_out, h, self.eta, &a_out.z, av_out,
            ) {
                return Some((
                    State {
                        z: z_in,
                        v: Some(v_in),
                    },
                    State {
                        z: a_z,
                        v: Some(a_v),
                    },
                    a_th,
                ));
            }
        }
        // host-composed fallback: ψ⁻¹ then vjp (two device calls)
        let s_in = self.invert(dynamics, t_out, h, s_out)?;
        let (a_in, a_theta) = self.step_vjp(dynamics, t_out - h, h, &s_in, a_out);
        Some((s_in, a_in, a_theta))
    }

    // ---- workspace path --------------------------------------------------

    fn step_into(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s: &State,
        out: &mut State,
        err: &mut Vec<f32>,
        ws: &mut SolverWorkspace,
    ) -> bool {
        let v = s.v.as_ref().expect("ALF needs augmented state (z, v)");
        let n = s.z.len();
        super::workspace::shape_state_n(out, n, true);
        ensure(err, n);
        let State { z: oz, v: ov } = out;
        let ov = ov.as_mut().expect("just shaped");
        self.psi_into(dynamics, t, h, &s.z, v, oz, ov, err, ws);
        true
    }

    fn step_vjp_into(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s_in: &State,
        a_out: &State,
        a_in: &mut State,
        ath_acc: &mut [f32],
        ws: &mut SolverWorkspace,
    ) {
        let v = s_in.v.as_ref().expect("ALF needs augmented state");
        let n = s_in.z.len();
        super::workspace::shape_state_n(a_in, n, true);
        // a_v(T) may be absent: substitute the workspace's read-only zero
        // cotangent, taken out so it can ride alongside `&mut ws`
        let mut zero_buf = std::mem::take(&mut ws.zero);
        if a_out.v.is_none() {
            ensure(&mut zero_buf, n);
        }
        let av_out: &[f32] = match &a_out.v {
            Some(av) => av,
            None => &zero_buf,
        };
        let State { z: az, v: av } = a_in;
        let av = av.as_mut().expect("just shaped");
        self.psi_vjp_into(dynamics, t, h, &s_in.z, v, &a_out.z, av_out, az, av, ath_acc, ws);
        ws.zero = zero_buf;
    }

    fn invert_into(
        &self,
        dynamics: &dyn Dynamics,
        t_out: f64,
        h: f64,
        s_out: &State,
        out: &mut State,
        ws: &mut SolverWorkspace,
    ) -> bool {
        let v = s_out.v.as_ref().expect("ALF needs augmented state");
        let n = s_out.z.len();
        super::workspace::shape_state_n(out, n, true);
        let State { z: oz, v: ov } = out;
        let ov = ov.as_mut().expect("just shaped");
        self.psi_inv_into(dynamics, t_out, h, &s_out.z, v, oz, ov, ws);
        true
    }

    fn invert_and_vjp_into(
        &self,
        dynamics: &dyn Dynamics,
        t_out: f64,
        h: f64,
        s_out: &State,
        a_out: &State,
        s_in: &mut State,
        a_in: &mut State,
        ath_acc: &mut [f32],
        ws: &mut SolverWorkspace,
    ) -> bool {
        let v_out = s_out.v.as_ref().expect("ALF needs augmented state");
        let n = s_out.z.len();
        if self.prefer_fused {
            let mut zero_buf = std::mem::take(&mut ws.zero);
            if a_out.v.is_none() {
                ensure(&mut zero_buf, n);
            }
            let av_out: &[f32] = match &a_out.v {
                Some(av) => av,
                None => &zero_buf,
            };
            super::workspace::shape_state_n(s_in, n, true);
            super::workspace::shape_state_n(a_in, n, true);
            let State { z: siz, v: siv } = s_in;
            let siv = siv.as_mut().expect("just shaped");
            let State { z: aiz, v: aiv } = a_in;
            let aiv = aiv.as_mut().expect("just shaped");
            let fused = dynamics.fused_alf_bwd_into(
                &s_out.z, v_out, t_out, h, self.eta, &a_out.z, av_out, siz, siv, aiz, aiv,
                ath_acc,
            );
            ws.zero = zero_buf;
            if fused {
                return true;
            }
        }
        // host-composed fallback: ψ⁻¹ then vjp
        self.invert_into(dynamics, t_out, h, s_out, s_in, ws);
        self.step_vjp_into(dynamics, t_out - h, h, s_in, a_out, a_in, ath_acc, ws);
        true
    }

    // ---- batched path ---------------------------------------------------

    fn init_batch(
        &self,
        dynamics: &dyn Dynamics,
        t0: f64,
        z0: &[f32],
        spec: &BatchSpec,
    ) -> BatchState {
        // v₀ = f(z₀, t₀) for every row, one batched call.
        let ts = vec![t0; spec.batch];
        let v0 = dynamics.f_batch(&ts, z0, spec);
        BatchState::from_flat_zv(z0.to_vec(), v0, *spec)
    }

    fn init_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        t0: f64,
        z0: &[f32],
        spec: &BatchSpec,
        out: &mut BatchState,
        ws: &mut BatchWorkspace,
    ) {
        // Same arithmetic as `init_batch` (one batched v₀ = f(z₀, t₀)
        // call) with every buffer recycled: `out` is re-shaped in place
        // and the per-row time vector crosses the `&mut ws` boundary via
        // the usual take/restore rule.
        crate::solvers::workspace::shape_batch_state(out, spec.batch, spec.n_z, true);
        out.z.data.copy_from_slice(z0);
        let mut ts = std::mem::take(&mut ws.ts_in);
        crate::solvers::workspace::ensure_f64(&mut ts, spec.batch);
        ts.fill(t0);
        let v = out.v.as_mut().expect("just shaped with v");
        dynamics.f_batch_into(&ts, z0, spec, &mut v.data);
        ws.ts_in = ts;
    }

    fn step_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s: &BatchState,
    ) -> (BatchState, Option<Vec<f32>>) {
        let spec = s.spec();
        let v = s.v.as_ref().expect("ALF needs augmented state (z, v)");
        let (z_out, v_out, err) = self.psi_batch(dynamics, ts, hs, &s.z.data, &v.data, &spec);
        (BatchState::from_flat_zv(z_out, v_out, spec), Some(err))
    }

    fn step_vjp_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s_in: &BatchState,
        a_out: &BatchState,
    ) -> (BatchState, Vec<f32>) {
        let spec = s_in.spec();
        let v = s_in.v.as_ref().expect("ALF needs augmented state");
        let zero;
        let av_out = match &a_out.v {
            Some(av) => av.data.as_slice(),
            None => {
                zero = vec![0.0f32; v.data.len()];
                &zero
            }
        };
        let (a_z, a_v, a_theta) = self.psi_vjp_batch(
            dynamics, ts, hs, &s_in.z.data, &v.data, &a_out.z.data, av_out, &spec,
        );
        (BatchState::from_flat_zv(a_z, a_v, spec), a_theta)
    }

    fn invert_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts_out: &[f64],
        hs: &[f64],
        s_out: &BatchState,
    ) -> Option<BatchState> {
        let spec = s_out.spec();
        let v = s_out.v.as_ref().expect("ALF needs augmented state");
        let (z_in, v_in) =
            self.psi_inv_batch(dynamics, ts_out, hs, &s_out.z.data, &v.data, &spec);
        Some(BatchState::from_flat_zv(z_in, v_in, spec))
    }

    // ---- batched workspace path -----------------------------------------

    fn step_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s: &BatchState,
        out: &mut BatchState,
        err: &mut Vec<f32>,
        ws: &mut BatchWorkspace,
    ) -> bool {
        let spec = s.spec();
        let v = s.v.as_ref().expect("ALF needs augmented state (z, v)");
        super::workspace::shape_batch_state(out, spec.batch, spec.n_z, true);
        ensure(err, spec.flat_len());
        let BatchState { z: oz, v: ov } = out;
        let ov = ov.as_mut().expect("just shaped");
        self.psi_batch_into(
            dynamics,
            ts,
            hs,
            &s.z.data,
            &v.data,
            &spec,
            &mut oz.data,
            &mut ov.data,
            err,
            ws,
        );
        true
    }

    fn step_vjp_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s_in: &BatchState,
        a_out: &BatchState,
        a_in: &mut BatchState,
        ath_acc: &mut [f32],
        ws: &mut BatchWorkspace,
    ) {
        let spec = s_in.spec();
        let v = s_in.v.as_ref().expect("ALF needs augmented state");
        super::workspace::shape_batch_state(a_in, spec.batch, spec.n_z, true);
        let mut zero_buf = std::mem::take(&mut ws.zero);
        if a_out.v.is_none() {
            ensure(&mut zero_buf, spec.flat_len());
        }
        let av_out: &[f32] = match &a_out.v {
            Some(av) => &av.data,
            None => &zero_buf,
        };
        let BatchState { z: az, v: av } = a_in;
        let av = av.as_mut().expect("just shaped");
        self.psi_vjp_batch_into(
            dynamics,
            ts,
            hs,
            &s_in.z.data,
            &v.data,
            &a_out.z.data,
            av_out,
            &spec,
            &mut az.data,
            &mut av.data,
            ath_acc,
            ws,
        );
        ws.zero = zero_buf;
    }

    fn invert_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        ts_out: &[f64],
        hs: &[f64],
        s_out: &BatchState,
        out: &mut BatchState,
        ws: &mut BatchWorkspace,
    ) -> bool {
        let spec = s_out.spec();
        let v = s_out.v.as_ref().expect("ALF needs augmented state");
        super::workspace::shape_batch_state(out, spec.batch, spec.n_z, true);
        let BatchState { z: oz, v: ov } = out;
        let ov = ov.as_mut().expect("just shaped");
        self.psi_inv_batch_into(
            dynamics,
            ts_out,
            hs,
            &s_out.z.data,
            &v.data,
            &spec,
            &mut oz.data,
            &mut ov.data,
            ws,
        );
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::dynamics::{LinearToy, MlpDynamics};
    use crate::util::rng::Rng;

    /// ψ⁻¹(ψ(x)) = x to float roundoff — the property MALI's constant-memory
    /// reconstruction rests on (paper: "Invertibility of ALF").
    #[test]
    fn psi_inverse_roundtrip_exact() {
        let mut rng = Rng::new(3);
        let dynamics = MlpDynamics::new(6, 8, &mut rng);
        for &eta in &[1.0, 0.9, 0.8, 0.7] {
            let solver = AlfSolver::new(eta);
            let z: Vec<f32> = (0..6).map(|i| 0.2 * i as f32 - 0.5).collect();
            let v = dynamics.f(0.0, &z);
            let (z1, v1, _) = solver.psi(&dynamics, 0.3, 0.17, &z, &v);
            let (z0, v0) = solver.psi_inv(&dynamics, 0.3 + 0.17, 0.17, &z1, &v1);
            for i in 0..6 {
                assert!(
                    (z0[i] - z[i]).abs() < 1e-5,
                    "eta {eta} z[{i}]: {} vs {}",
                    z0[i],
                    z[i]
                );
                assert!((v0[i] - v[i]).abs() < 1e-5, "eta {eta} v[{i}]");
            }
        }
    }

    /// Local truncation error of z is O(h³) when v is consistent
    /// (Theorem 3.1): halving h should cut the one-step error by ~8×.
    #[test]
    fn local_truncation_order_three() {
        let toy = LinearToy::new(1.0, 1);
        let solver = AlfSolver::new(1.0);
        let z0 = [1.0f32];
        let mut errs = Vec::new();
        for &h in &[0.2f64, 0.1, 0.05] {
            let v0 = toy.f(0.0, &z0);
            let (z1, _, _) = solver.psi(&toy, 0.0, h, &z0, &v0);
            let exact = (h).exp() as f32;
            errs.push(((z1[0] - exact).abs()) as f64);
        }
        // ratio between consecutive errors ≈ 2³ = 8 (allow slack)
        for w in errs.windows(2) {
            let ratio = w[0] / w[1].max(1e-300);
            assert!(ratio > 5.0, "expected ~8x decay, got {ratio} ({errs:?})");
        }
    }

    /// vjp of ψ matches central finite differences on (z, v, θ).
    #[test]
    fn psi_vjp_matches_finite_differences() {
        let mut rng = Rng::new(5);
        let mut dynamics = MlpDynamics::new(3, 5, &mut rng);
        let solver = AlfSolver::new(0.9);
        let (t, h) = (0.1, 0.23);
        let z: Vec<f32> = vec![0.3, -0.2, 0.5];
        let v = dynamics.f(t, &z);
        let az_out: Vec<f32> = vec![1.0, -0.5, 0.25];
        let av_out: Vec<f32> = vec![0.2, 0.4, -0.3];
        let (a_z, a_v, a_th) = solver.psi_vjp(&dynamics, t, h, &z, &v, &az_out, &av_out);

        let scalar = |zz: &[f32], vv: &[f32], d: &MlpDynamics| -> f64 {
            let (z1, v1, _) = solver.psi(d, t, h, zz, vv);
            z1.iter()
                .zip(&az_out)
                .chain(v1.iter().zip(&av_out))
                .map(|(&x, &c)| x as f64 * c as f64)
                .sum()
        };
        let eps = 1e-3;
        for j in 0..z.len() {
            let mut zp = z.clone();
            zp[j] += eps as f32;
            let mut zm = z.clone();
            zm[j] -= eps as f32;
            let fd = (scalar(&zp, &v, &dynamics) - scalar(&zm, &v, &dynamics)) / (2.0 * eps);
            assert!((fd - a_z[j] as f64).abs() < 5e-3, "a_z[{j}]: {fd} vs {}", a_z[j]);
        }
        for j in 0..v.len() {
            let mut vp = v.clone();
            vp[j] += eps as f32;
            let mut vm = v.clone();
            vm[j] -= eps as f32;
            let fd = (scalar(&z, &vp, &dynamics) - scalar(&z, &vm, &dynamics)) / (2.0 * eps);
            assert!((fd - a_v[j] as f64).abs() < 5e-3, "a_v[{j}]: {fd} vs {}", a_v[j]);
        }
        let theta0 = dynamics.params().to_vec();
        for &k in &[0usize, 7, theta0.len() - 1] {
            let mut tp = theta0.clone();
            tp[k] += eps as f32;
            dynamics.set_params(&tp);
            let fp = scalar(&z, &v, &dynamics);
            let mut tm = theta0.clone();
            tm[k] -= eps as f32;
            dynamics.set_params(&tm);
            let fm = scalar(&z, &v, &dynamics);
            dynamics.set_params(&theta0);
            let fd = (fp - fm) / (2.0 * eps);
            assert!(
                (fd - a_th[k] as f64).abs() < 5e-3,
                "a_θ[{k}]: {fd} vs {}",
                a_th[k]
            );
        }
    }

    /// Batched ψ / ψ⁻¹ / ψ-vjp with *desynchronized* per-row `(t, h)` must
    /// equal the single-sample methods row-for-row (bitwise: the same f32
    /// operation sequence) — the invariant the batch/single equivalence
    /// suite rests on.
    #[test]
    fn batched_psi_matches_rows_exactly() {
        let mut rng = Rng::new(9);
        let dynamics = MlpDynamics::new(3, 5, &mut rng);
        let solver = AlfSolver::new(0.8);
        let spec = crate::solvers::batch::BatchSpec::new(3, 3);
        let mut z = vec![0.0f32; spec.flat_len()];
        rng.fill_normal(&mut z, 0.5);
        let ts = [0.0, 0.3, 0.7];
        let hs = [0.1, 0.25, 0.05];
        // consistent per-row v₀
        let v = dynamics.f_batch(&ts, &z, &spec);

        let (zb, vb, eb) = solver.psi_batch(&dynamics, &ts, &hs, &z, &v, &spec);
        for b in 0..3 {
            let (zs, vs, es) =
                solver.psi(&dynamics, ts[b], hs[b], spec.row(&z, b), spec.row(&v, b));
            assert_eq!(spec.row(&zb, b), zs.as_slice(), "z row {b}");
            assert_eq!(spec.row(&vb, b), vs.as_slice(), "v row {b}");
            assert_eq!(spec.row(&eb, b), es.as_slice(), "err row {b}");
        }

        // inverse round-trip, batched
        let ts_out: Vec<f64> = ts.iter().zip(&hs).map(|(&t, &h)| t + h).collect();
        let (z0b, v0b) = solver.psi_inv_batch(&dynamics, &ts_out, &hs, &zb, &vb, &spec);
        for i in 0..spec.flat_len() {
            assert!((z0b[i] - z[i]).abs() < 1e-5, "inv z[{i}]");
            assert!((v0b[i] - v[i]).abs() < 1e-5, "inv v[{i}]");
        }

        // vjp rows
        let mut az = vec![0.0f32; spec.flat_len()];
        let mut av = vec![0.0f32; spec.flat_len()];
        rng.fill_normal(&mut az, 1.0);
        rng.fill_normal(&mut av, 1.0);
        let (azb, avb, athb) =
            solver.psi_vjp_batch(&dynamics, &ts, &hs, &z, &v, &az, &av, &spec);
        let mut ath_sum = vec![0.0f32; dynamics.param_dim()];
        for b in 0..3 {
            let (azs, avs, aths) = solver.psi_vjp(
                &dynamics,
                ts[b],
                hs[b],
                spec.row(&z, b),
                spec.row(&v, b),
                spec.row(&az, b),
                spec.row(&av, b),
            );
            assert_eq!(spec.row(&azb, b), azs.as_slice(), "a_z row {b}");
            assert_eq!(spec.row(&avb, b), avs.as_slice(), "a_v row {b}");
            axpy(1.0, &aths, &mut ath_sum);
        }
        for (k, (&got, &want)) in athb.iter().zip(&ath_sum).enumerate() {
            assert!((got - want).abs() < 1e-4, "a_θ[{k}]: {got} vs {want}");
        }
    }

    #[test]
    fn damped_alf_reduces_to_alf_at_eta_one() {
        let toy = LinearToy::new(-0.7, 2);
        let z = [1.0f32, 2.0];
        let v = toy.f(0.0, &z);
        let a = AlfSolver::new(1.0).psi(&toy, 0.0, 0.1, &z, &v);
        // η = 1 − 1e-12 ≈ 1
        let b = AlfSolver::new(1.0 - 1e-12).psi(&toy, 0.0, 0.1, &z, &v);
        for i in 0..2 {
            assert!((a.0[i] - b.0[i]).abs() < 1e-5);
            assert!((a.1[i] - b.1[i]).abs() < 1e-5);
        }
    }

    #[test]
    #[should_panic]
    fn eta_below_half_rejected() {
        AlfSolver::new(0.4);
    }
}
