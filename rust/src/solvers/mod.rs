//! Numerical ODE solvers: the (damped) ALF integrator at the heart of MALI
//! plus the classical explicit RK family used as baselines and inference
//! solvers, and the adaptive integration loop (paper Algo. 1).

pub mod alf;
pub mod dynamics;
pub mod integrate;
pub mod rk;
pub mod stability;

use dynamics::Dynamics;

/// Solver state: plain `z` for RK methods, augmented `(z, v)` for ALF.
#[derive(Debug, Clone, PartialEq)]
pub struct State {
    /// The ODE state `z(t)` (flattened batch × features).
    pub z: Vec<f32>,
    /// ALF's auxiliary velocity `v ≈ dz/dt`; `None` for plain RK states.
    pub v: Option<Vec<f32>>,
}

impl State {
    /// Wrap a plain (non-augmented) state vector.
    pub fn from_z(z: Vec<f32>) -> State {
        State { z, v: None }
    }

    /// Logical size in bytes (for MemTracker accounting).
    pub fn bytes(&self) -> usize {
        (self.z.len() + self.v.as_ref().map_or(0, |v| v.len())) * 4
    }

    /// Zero cotangent of the same shape.
    pub fn zeros_like(&self) -> State {
        State {
            z: vec![0.0; self.z.len()],
            v: self.v.as_ref().map(|v| vec![0.0; v.len()]),
        }
    }
}

/// One numerical integration method ψ (paper notation): everything the
/// adaptive loop and the four gradient protocols need from a solver.
pub trait Solver {
    /// Stable identifier used in configs, CLI flags and report tables.
    fn name(&self) -> &'static str;

    /// Classical order p (used for the step-size controller exponent).
    fn order(&self) -> usize;

    /// Whether [`Solver::step`] returns an embedded error estimate —
    /// required by the adaptive loop (`StepMode::Adaptive`).
    fn has_error_estimate(&self) -> bool;

    /// Build the initial solver state from `z₀` (ALF also computes
    /// `v₀ = f(z₀, t₀)`).
    fn init(&self, dynamics: &dyn Dynamics, t0: f64, z0: &[f32]) -> State;

    /// One step `ψ_h(t, s)`; returns the new state and (if available) the
    /// embedded error-estimate vector.
    fn step(&self, dynamics: &dyn Dynamics, t: f64, h: f64, s: &State)
        -> (State, Option<Vec<f32>>);

    /// Reverse-mode vjp through one step: cotangents on the outputs pulled
    /// back to cotangents on the input state, plus `∂/∂θ` contributions.
    fn step_vjp(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s_in: &State,
        a_out: &State,
    ) -> (State, Vec<f32>);

    /// Exact step inverse ψ⁻¹ where one exists (ALF); `None` otherwise.
    fn invert(
        &self,
        dynamics: &dyn Dynamics,
        t_out: f64,
        h: f64,
        s_out: &State,
    ) -> Option<State>;

    /// `true` iff [`Solver::invert`] is exact — the property MALI requires
    /// of its training solver (paper §3.1).
    fn is_invertible(&self) -> bool {
        false
    }

    /// One MALI backward micro-step: reconstruct the step input via ψ⁻¹
    /// and pull the cotangents through the step.  Returns
    /// `(s_in, a_in, a_θ)`.  The default composes [`Solver::invert`] +
    /// [`Solver::step_vjp`]; ALF overrides it with the fused device path
    /// when the dynamics exports one.
    fn invert_and_vjp(
        &self,
        dynamics: &dyn Dynamics,
        t_out: f64,
        h: f64,
        s_out: &State,
        a_out: &State,
    ) -> Option<(State, State, Vec<f32>)> {
        let s_in = self.invert(dynamics, t_out, h, s_out)?;
        let (a_in, a_theta) = self.step_vjp(dynamics, t_out - h, h, &s_in, a_out);
        Some((s_in, a_in, a_theta))
    }
}

/// Named solver construction — the strings used in configs, CLI and the
/// Table-2 / Table-3 grids.
pub fn by_name(name: &str) -> anyhow::Result<Box<dyn Solver>> {
    by_name_eta(name, 1.0)
}

/// Like [`by_name`] but with an explicit ALF damping coefficient (Table 7).
pub fn by_name_eta(name: &str, eta: f64) -> anyhow::Result<Box<dyn Solver>> {
    use rk::{RkSolver, Tableau};
    Ok(match name {
        "alf" | "mali" => Box::new(alf::AlfSolver::new(eta)),
        "euler" => Box::new(RkSolver::new(Tableau::euler())),
        "midpoint" | "rk2" => Box::new(RkSolver::new(Tableau::midpoint())),
        "rk4" => Box::new(RkSolver::new(Tableau::rk4())),
        "heun-euler" | "heun_euler" => Box::new(RkSolver::new(Tableau::heun_euler())),
        "rk23" => Box::new(RkSolver::new(Tableau::rk23())),
        "dopri5" => Box::new(RkSolver::new(Tableau::dopri5())),
        other => anyhow::bail!("unknown solver '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use dynamics::LinearToy;

    #[test]
    fn factory_knows_all_solvers() {
        for name in ["alf", "euler", "rk2", "rk4", "heun-euler", "rk23", "dopri5"] {
            let s = by_name(name).unwrap();
            assert!(!s.name().is_empty());
        }
        assert!(by_name("nope").is_err());
    }

    #[test]
    fn state_bytes_counts_augmented() {
        let s = State {
            z: vec![0.0; 10],
            v: Some(vec![0.0; 10]),
        };
        assert_eq!(s.bytes(), 80);
        assert_eq!(State::from_z(vec![0.0; 10]).bytes(), 40);
    }

    #[test]
    fn alf_init_sets_v_to_f() {
        let toy = LinearToy::new(2.0, 2);
        let s = by_name("alf").unwrap().init(&toy, 0.0, &[1.0, 3.0]);
        assert_eq!(s.v.unwrap(), vec![2.0, 6.0]);
    }
}
