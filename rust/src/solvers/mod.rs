//! Numerical ODE solvers: the (damped) ALF integrator at the heart of MALI
//! plus the classical explicit RK family used as baselines and inference
//! solvers, and the adaptive integration loop (paper Algo. 1).

pub mod alf;
pub mod batch;
pub mod dynamics;
pub mod integrate;
pub mod reversible;
pub mod rk;
pub mod stability;
pub mod workspace;

use batch::{BatchSpec, BatchState};
use dynamics::Dynamics;
use workspace::{BatchWorkspace, SolverWorkspace};

/// Solver state: plain `z` for RK methods, augmented `(z, v)` for ALF.
#[derive(Debug, Clone, PartialEq)]
pub struct State {
    /// The ODE state `z(t)` (flattened batch × features).
    pub z: Vec<f32>,
    /// ALF's auxiliary velocity `v ≈ dz/dt`; `None` for plain RK states.
    pub v: Option<Vec<f32>>,
}

impl State {
    /// Wrap a plain (non-augmented) state vector.
    pub fn from_z(z: Vec<f32>) -> State {
        State { z, v: None }
    }

    /// Logical size in bytes (for MemTracker accounting).
    pub fn bytes(&self) -> usize {
        (self.z.len() + self.v.as_ref().map_or(0, |v| v.len())) * 4
    }

    /// Zero cotangent of the same shape.
    pub fn zeros_like(&self) -> State {
        State {
            z: vec![0.0; self.z.len()],
            v: self.v.as_ref().map(|v| vec![0.0; v.len()]),
        }
    }
}

/// One numerical integration method ψ (paper notation): everything the
/// adaptive loop and the four gradient protocols need from a solver.
pub trait Solver {
    /// Stable identifier used in configs, CLI flags and report tables.
    fn name(&self) -> &'static str;

    /// Classical order p (used for the step-size controller exponent).
    fn order(&self) -> usize;

    /// Whether [`Solver::step`] returns an embedded error estimate —
    /// required by the adaptive loop (`StepMode::Adaptive`).
    fn has_error_estimate(&self) -> bool;

    /// Build the initial solver state from `z₀` (ALF also computes
    /// `v₀ = f(z₀, t₀)`).
    fn init(&self, dynamics: &dyn Dynamics, t0: f64, z0: &[f32]) -> State;

    /// One step `ψ_h(t, s)`; returns the new state and (if available) the
    /// embedded error-estimate vector.
    fn step(&self, dynamics: &dyn Dynamics, t: f64, h: f64, s: &State)
        -> (State, Option<Vec<f32>>);

    /// Reverse-mode vjp through one step: cotangents on the outputs pulled
    /// back to cotangents on the input state, plus `∂/∂θ` contributions.
    fn step_vjp(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s_in: &State,
        a_out: &State,
    ) -> (State, Vec<f32>);

    /// Exact step inverse ψ⁻¹ where one exists (ALF); `None` otherwise.
    fn invert(
        &self,
        dynamics: &dyn Dynamics,
        t_out: f64,
        h: f64,
        s_out: &State,
    ) -> Option<State>;

    /// `true` iff [`Solver::invert`] is exact — the property MALI requires
    /// of its training solver (paper §3.1).
    fn is_invertible(&self) -> bool {
        false
    }

    /// One MALI backward micro-step: reconstruct the step input via ψ⁻¹
    /// and pull the cotangents through the step.  Returns
    /// `(s_in, a_in, a_θ)`.  The default composes [`Solver::invert`] +
    /// [`Solver::step_vjp`]; ALF overrides it with the fused device path
    /// when the dynamics exports one.
    fn invert_and_vjp(
        &self,
        dynamics: &dyn Dynamics,
        t_out: f64,
        h: f64,
        s_out: &State,
        a_out: &State,
    ) -> Option<(State, State, Vec<f32>)> {
        let s_in = self.invert(dynamics, t_out, h, s_out)?;
        let (a_in, a_theta) = self.step_vjp(dynamics, t_out - h, h, &s_in, a_out);
        Some((s_in, a_in, a_theta))
    }

    // ---- workspace (allocation-free) entry points ----------------------
    //
    // The `_into` variants write into caller-provided buffers and draw
    // scratch from a [`SolverWorkspace`] / [`BatchWorkspace`]; after the
    // buffers reach their steady shapes the overriding solvers (ALF, RK)
    // perform zero heap allocations per call.  The defaults forward to
    // the allocating methods — correct for any solver, value-identical.
    // Output buffers are re-shaped by the callee, so callers only need
    // to hand in *some* recycled `State`.

    /// One step ψ into caller buffers: `out` receives the new state and
    /// `err` the embedded error estimate when the solver has one (the
    /// return value says whether `err` was written).  Default forwards
    /// to [`Solver::step`].
    #[allow(clippy::too_many_arguments)]
    fn step_into(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s: &State,
        out: &mut State,
        err: &mut Vec<f32>,
        ws: &mut SolverWorkspace,
    ) -> bool {
        let _ = ws;
        let (next, e) = self.step(dynamics, t, h, s);
        *out = next;
        match e {
            Some(e) => {
                *err = e;
                true
            }
            None => false,
        }
    }

    /// Reverse-mode vjp through one step into caller buffers; the
    /// θ-cotangent is **accumulated** into `ath_acc` (bit-identical to
    /// the `axpy(1.0, ..)` the gradient loops previously performed on the
    /// returned vector).  Default forwards to [`Solver::step_vjp`].
    #[allow(clippy::too_many_arguments)]
    fn step_vjp_into(
        &self,
        dynamics: &dyn Dynamics,
        t: f64,
        h: f64,
        s_in: &State,
        a_out: &State,
        a_in: &mut State,
        ath_acc: &mut [f32],
        ws: &mut SolverWorkspace,
    ) {
        let _ = ws;
        let (a, dth) = self.step_vjp(dynamics, t, h, s_in, a_out);
        *a_in = a;
        crate::tensor::axpy(1.0, &dth, ath_acc);
    }

    /// Exact step inverse ψ⁻¹ into a caller buffer; returns `false` when
    /// the solver is not invertible.  Default forwards to
    /// [`Solver::invert`].
    fn invert_into(
        &self,
        dynamics: &dyn Dynamics,
        t_out: f64,
        h: f64,
        s_out: &State,
        out: &mut State,
        ws: &mut SolverWorkspace,
    ) -> bool {
        let _ = ws;
        match self.invert(dynamics, t_out, h, s_out) {
            Some(s) => {
                *out = s;
                true
            }
            None => false,
        }
    }

    /// MALI backward micro-step into caller buffers: ψ⁻¹ reconstruction
    /// plus the step vjp, θ-cotangent accumulated into `ath_acc`.
    /// Returns `false` when the solver is not invertible.  Default
    /// forwards to [`Solver::invert_and_vjp`].
    #[allow(clippy::too_many_arguments)]
    fn invert_and_vjp_into(
        &self,
        dynamics: &dyn Dynamics,
        t_out: f64,
        h: f64,
        s_out: &State,
        a_out: &State,
        s_in: &mut State,
        a_in: &mut State,
        ath_acc: &mut [f32],
        ws: &mut SolverWorkspace,
    ) -> bool {
        let _ = ws;
        match self.invert_and_vjp(dynamics, t_out, h, s_out, a_out) {
            Some((s, a, dth)) => {
                *s_in = s;
                *a_in = a;
                crate::tensor::axpy(1.0, &dth, ath_acc);
                true
            }
            None => false,
        }
    }

    /// Batched [`Solver::step_into`] with per-row `(t, h)`.  Default
    /// forwards to [`Solver::step_batch`].
    #[allow(clippy::too_many_arguments)]
    fn step_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s: &BatchState,
        out: &mut BatchState,
        err: &mut Vec<f32>,
        ws: &mut BatchWorkspace,
    ) -> bool {
        let _ = ws;
        let (next, e) = self.step_batch(dynamics, ts, hs, s);
        *out = next;
        match e {
            Some(e) => {
                *err = e;
                true
            }
            None => false,
        }
    }

    /// Batched [`Solver::step_vjp_into`]; θ-cotangents are summed over
    /// rows and accumulated into `ath_acc`.  Default forwards to
    /// [`Solver::step_vjp_batch`].
    #[allow(clippy::too_many_arguments)]
    fn step_vjp_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s_in: &BatchState,
        a_out: &BatchState,
        a_in: &mut BatchState,
        ath_acc: &mut [f32],
        ws: &mut BatchWorkspace,
    ) {
        let _ = ws;
        let (a, dth) = self.step_vjp_batch(dynamics, ts, hs, s_in, a_out);
        *a_in = a;
        crate::tensor::axpy(1.0, &dth, ath_acc);
    }

    /// Batched [`Solver::invert_into`] with per-row `(t_out, h)`; returns
    /// `false` when the solver is not invertible.  Default forwards to
    /// [`Solver::invert_batch`].
    #[allow(clippy::too_many_arguments)]
    fn invert_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        ts_out: &[f64],
        hs: &[f64],
        s_out: &BatchState,
        out: &mut BatchState,
        ws: &mut BatchWorkspace,
    ) -> bool {
        let _ = ws;
        match self.invert_batch(dynamics, ts_out, hs, s_out) {
            Some(s) => {
                *out = s;
                true
            }
            None => false,
        }
    }

    /// [`Solver::init_batch`] into a caller-recycled buffer: `out` is
    /// re-shaped to `[spec.batch, spec.n_z]` and filled with the batched
    /// initial state.  The default forwards to the allocating
    /// [`Solver::init_batch`]; ALF and RK override it in place so a warm
    /// serving loop can admit new requests without touching the allocator
    /// (the `serve` worker's entry path).
    fn init_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        t0: f64,
        z0: &[f32],
        spec: &BatchSpec,
        out: &mut BatchState,
        ws: &mut BatchWorkspace,
    ) {
        let _ = ws;
        *out = self.init_batch(dynamics, t0, z0, spec);
    }

    /// Batched MALI backward micro-step into caller buffers.  The default
    /// composes [`Solver::invert_batch_into`] +
    /// [`Solver::step_vjp_batch_into`] — allocation-free whenever those
    /// are.  Returns `false` when the solver is not invertible.
    #[allow(clippy::too_many_arguments)]
    fn invert_and_vjp_batch_into(
        &self,
        dynamics: &dyn Dynamics,
        ts_out: &[f64],
        hs: &[f64],
        s_out: &BatchState,
        a_out: &BatchState,
        s_in: &mut BatchState,
        a_in: &mut BatchState,
        ath_acc: &mut [f32],
        ws: &mut BatchWorkspace,
    ) -> bool {
        if !self.invert_batch_into(dynamics, ts_out, hs, s_out, s_in, ws) {
            return false;
        }
        // per-row step-input times; the buffer is taken out of the
        // workspace so it can be passed alongside `&mut ws`
        let mut ts_in = std::mem::take(&mut ws.ts_in);
        workspace::ensure_f64(&mut ts_in, ts_out.len());
        for ((ti, &to), &h) in ts_in.iter_mut().zip(ts_out).zip(hs) {
            *ti = to - h;
        }
        self.step_vjp_batch_into(dynamics, &ts_in, hs, s_in, a_out, a_in, ath_acc, ws);
        ws.ts_in = ts_in;
        true
    }

    // ---- batch-first entry points --------------------------------------
    //
    // A [`BatchState`] carries `B` independent trajectories as `[B, N_z]`
    // rows; per-sample adaptive stepping desynchronizes rows, so every
    // batched method takes per-row times `ts` and step sizes `hs`.  The
    // defaults loop rows through the single-sample methods (correct for
    // any solver); `AlfSolver`/`RkSolver` override them with stage
    // arithmetic over the flat buffer and one batched `f` call per stage.

    /// Build the batched initial state from `[B, N_z]` rows of `z₀`.
    fn init_batch(
        &self,
        dynamics: &dyn Dynamics,
        t0: f64,
        z0: &[f32],
        spec: &BatchSpec,
    ) -> BatchState {
        let states: Vec<State> = (0..spec.batch)
            .map(|b| self.init(dynamics, t0, spec.row(z0, b)))
            .collect();
        let refs: Vec<&State> = states.iter().collect();
        BatchState::from_states(&refs)
    }

    /// One batched step with per-row `(t, h)`; the error estimate (if any)
    /// is a flat `[B, N_z]` buffer of per-row embedded errors.
    fn step_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s: &BatchState,
    ) -> (BatchState, Option<Vec<f32>>) {
        let spec = s.spec();
        debug_assert_eq!(ts.len(), spec.batch);
        debug_assert_eq!(hs.len(), spec.batch);
        let mut states = Vec::with_capacity(spec.batch);
        let mut err_flat = Vec::with_capacity(spec.flat_len());
        let mut have_err = true;
        for b in 0..spec.batch {
            let (next, err) = self.step(dynamics, ts[b], hs[b], &s.row_state(b));
            match err {
                Some(e) => err_flat.extend_from_slice(&e),
                None => have_err = false,
            }
            states.push(next);
        }
        let refs: Vec<&State> = states.iter().collect();
        (
            BatchState::from_states(&refs),
            if have_err { Some(err_flat) } else { None },
        )
    }

    /// Reverse-mode vjp through one batched step; θ-cotangents are summed
    /// over rows (the mini-batch gradient).
    fn step_vjp_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts: &[f64],
        hs: &[f64],
        s_in: &BatchState,
        a_out: &BatchState,
    ) -> (BatchState, Vec<f32>) {
        let spec = s_in.spec();
        let mut states = Vec::with_capacity(spec.batch);
        let mut a_theta = vec![0.0f32; dynamics.param_dim()];
        for b in 0..spec.batch {
            let (a_in, dth) =
                self.step_vjp(dynamics, ts[b], hs[b], &s_in.row_state(b), &a_out.row_state(b));
            crate::tensor::axpy(1.0, &dth, &mut a_theta);
            states.push(a_in);
        }
        let refs: Vec<&State> = states.iter().collect();
        (BatchState::from_states(&refs), a_theta)
    }

    /// Batched exact step inverse ψ⁻¹ with per-row `(t_out, h)`; `None`
    /// when the solver is not invertible.
    fn invert_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts_out: &[f64],
        hs: &[f64],
        s_out: &BatchState,
    ) -> Option<BatchState> {
        if !self.is_invertible() {
            return None;
        }
        let spec = s_out.spec();
        let mut states = Vec::with_capacity(spec.batch);
        for b in 0..spec.batch {
            states.push(self.invert(dynamics, ts_out[b], hs[b], &s_out.row_state(b))?);
        }
        let refs: Vec<&State> = states.iter().collect();
        Some(BatchState::from_states(&refs))
    }

    /// Batched MALI backward micro-step: ψ⁻¹ reconstruction plus the step
    /// vjp for every row.  Default composes [`Solver::invert_batch`] +
    /// [`Solver::step_vjp_batch`].
    fn invert_and_vjp_batch(
        &self,
        dynamics: &dyn Dynamics,
        ts_out: &[f64],
        hs: &[f64],
        s_out: &BatchState,
        a_out: &BatchState,
    ) -> Option<(BatchState, BatchState, Vec<f32>)> {
        let s_in = self.invert_batch(dynamics, ts_out, hs, s_out)?;
        let ts_in: Vec<f64> = ts_out.iter().zip(hs).map(|(&t, &h)| t - h).collect();
        let (a_in, a_theta) = self.step_vjp_batch(dynamics, &ts_in, hs, &s_in, a_out);
        Some((s_in, a_in, a_theta))
    }
}

/// Named solver construction — the strings used in configs, CLI and the
/// Table-2 / Table-3 grids.  The box is `Send + Sync` so a solver can be
/// shared across `util::pool` workers by the batched gradient driver.
pub fn by_name(name: &str) -> anyhow::Result<Box<dyn Solver + Send + Sync>> {
    by_name_eta(name, 1.0)
}

/// Like [`by_name`] but with an explicit ALF damping coefficient (Table 7).
pub fn by_name_eta(name: &str, eta: f64) -> anyhow::Result<Box<dyn Solver + Send + Sync>> {
    use rk::{RkSolver, Tableau};
    Ok(match name {
        "alf" | "mali" => Box::new(alf::AlfSolver::new(eta)),
        "reversible4" | "reversible-4" | "rev4" => Box::new(reversible::Reversible4::new(eta)),
        "euler" => Box::new(RkSolver::new(Tableau::euler())),
        "midpoint" | "rk2" => Box::new(RkSolver::new(Tableau::midpoint())),
        "rk4" => Box::new(RkSolver::new(Tableau::rk4())),
        "heun-euler" | "heun_euler" => Box::new(RkSolver::new(Tableau::heun_euler())),
        "rk23" => Box::new(RkSolver::new(Tableau::rk23())),
        "dopri5" => Box::new(RkSolver::new(Tableau::dopri5())),
        other => anyhow::bail!("unknown solver '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use dynamics::LinearToy;

    #[test]
    fn factory_knows_all_solvers() {
        for name in [
            "alf",
            "reversible4",
            "rev4",
            "euler",
            "rk2",
            "rk4",
            "heun-euler",
            "rk23",
            "dopri5",
        ] {
            let s = by_name(name).unwrap();
            assert!(!s.name().is_empty());
        }
        assert!(by_name("nope").is_err());
    }

    #[test]
    fn state_bytes_counts_augmented() {
        let s = State {
            z: vec![0.0; 10],
            v: Some(vec![0.0; 10]),
        };
        assert_eq!(s.bytes(), 80);
        assert_eq!(State::from_z(vec![0.0; 10]).bytes(), 40);
    }

    #[test]
    fn alf_init_sets_v_to_f() {
        let toy = LinearToy::new(2.0, 2);
        let s = by_name("alf").unwrap().init(&toy, 0.0, &[1.0, 3.0]);
        assert_eq!(s.v.unwrap(), vec![2.0, 6.0]);
    }

    /// The in-place batched init (the serve worker's admission path) is
    /// bitwise the allocating `init_batch`, including re-shaping a
    /// recycled buffer of the wrong shape / `v`-ness.
    #[test]
    fn init_batch_into_matches_init_batch() {
        use crate::tensor::Tensor;
        let toy = LinearToy::new(0.7, 2);
        let spec = BatchSpec::new(3, 2);
        let z0: Vec<f32> = (0..6).map(|i| 0.3 * i as f32 - 0.5).collect();
        for name in ["alf", "dopri5"] {
            let s = by_name(name).unwrap();
            let reference = s.init_batch(&toy, 0.25, &z0, &spec);
            let mut ws = workspace::BatchWorkspace::new();
            // start from a deliberately mis-shaped recycled buffer
            let mut out = BatchState {
                z: Tensor {
                    data: vec![9.0; 4],
                    shape: vec![2, 2],
                },
                v: name.starts_with('d').then(|| Tensor {
                    data: vec![9.0; 4],
                    shape: vec![2, 2],
                }),
            };
            s.init_batch_into(&toy, 0.25, &z0, &spec, &mut out, &mut ws);
            assert_eq!(out, reference, "{name}");
        }
    }
}
