//! Deep MLP dynamics lowered onto `tensor::matmul_into`.
//!
//! `f(t, z) = Wₗ·tanh(…tanh(W₁·x + b₁)…) + bₗ` with `x` the state plus
//! optional time conditioning — the native analogue of the L2 `mlp_f_t`
//! graph (`python/compile/kernels/ref.py`: time enters as an extra input
//! feature).  The forward is one matmul per layer over the whole
//! `[B, n]` batch; the hand-written vjp stages activations once and walks
//! the stack backwards with cached `Wᵀ` matrices, so both directions are
//! matmul-bound and allocation-free once warm.

use super::{
    ensure_layers, impl_dynamics_via_native_layered, LayerScratch, NativeLayered, ScratchPool,
};
use crate::solvers::dynamics::EvalCounters;
use crate::solvers::workspace::ensure;
use crate::tensor::{axpy, matmul_into};
use crate::util::rng::Rng;

/// How the MLP conditions on integration time `t`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimeMode {
    /// Autonomous: `f(z)` ignores `t`.
    None,
    /// `t` is appended to the input features (layer-1 weights get one
    /// extra input column) — the `mlp_f_t` convention of the L1 oracle.
    Concat,
    /// A learned per-unit `t·tw` term is added to the first layer's
    /// pre-activation.
    Affine,
}

/// Deep MLP right-hand side: affine → tanh stack, last layer affine.
///
/// θ layout (flat): per layer `W` (`in×out`, row-major, so the forward is
/// `x @ W + b` like the Python reference) then `b` (`out`), followed by
/// the time-affine vector `tw` (`dims[1]`) when [`TimeMode::Affine`].
#[derive(Debug)]
pub struct MlpDynamics {
    n_state: usize,
    time: TimeMode,
    /// Layer interface widths `[in_feat, h₁, …, n_state]`.
    dims: Vec<usize>,
    theta: Vec<f32>,
    w_off: Vec<usize>,
    b_off: Vec<usize>,
    tw_off: usize,
    /// Cached `Wᵀ` per layer (`out×in`) for `d_x = d_pre · Wᵀ`; rebuilt by
    /// `set_params` — the only place θ changes.
    wt: Vec<Vec<f32>>,
    counters: EvalCounters,
    pool: ScratchPool,
}

impl MlpDynamics {
    /// Random-init MLP with hidden widths `hidden` (may be empty for a
    /// single affine layer).
    pub fn new(n_state: usize, hidden: &[usize], time: TimeMode, rng: &mut Rng) -> Self {
        assert!(n_state > 0, "MlpDynamics needs n_state > 0");
        assert!(
            hidden.iter().all(|&w| w > 0),
            "hidden widths must be positive: {hidden:?}"
        );
        let in_feat = n_state + (time == TimeMode::Concat) as usize;
        let mut dims = Vec::with_capacity(hidden.len() + 2);
        dims.push(in_feat);
        dims.extend_from_slice(hidden);
        dims.push(n_state);
        let layers = dims.len() - 1;
        let mut w_off = Vec::with_capacity(layers);
        let mut b_off = Vec::with_capacity(layers);
        let mut off = 0usize;
        for l in 0..layers {
            w_off.push(off);
            off += dims[l] * dims[l + 1];
            b_off.push(off);
            off += dims[l + 1];
        }
        let tw_off = off;
        if time == TimeMode::Affine {
            off += dims[1];
        }
        let mut theta = vec![0.0f32; off];
        // modest fan-in-scaled init so trajectories stay tame over T ~ 1
        for l in 0..layers {
            let std = 0.6 / (dims[l] as f64).sqrt();
            rng.fill_normal(&mut theta[w_off[l]..w_off[l] + dims[l] * dims[l + 1]], std);
        }
        if time == TimeMode::Affine {
            rng.fill_normal(&mut theta[tw_off..], 0.1);
        }
        let mut m = MlpDynamics {
            n_state,
            time,
            dims,
            theta,
            w_off,
            b_off,
            tw_off,
            wt: Vec::new(),
            counters: EvalCounters::default(),
            pool: ScratchPool::new(),
        };
        m.rebuild_wt();
        m
    }

    /// Layer interface widths `[in_feat, h₁, …, n_state]`.
    pub fn layer_dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn time_mode(&self) -> TimeMode {
        self.time
    }

    fn rebuild_wt(&mut self) {
        let layers = self.dims.len() - 1;
        while self.wt.len() < layers {
            self.wt.push(Vec::new());
        }
        for l in 0..layers {
            let (ind, outd) = (self.dims[l], self.dims[l + 1]);
            let w = &self.theta[self.w_off[l]..self.w_off[l] + ind * outd];
            let wt = &mut self.wt[l];
            ensure(wt, outd * ind);
            for i in 0..ind {
                for o in 0..outd {
                    wt[o * ind + i] = w[i * outd + o];
                }
            }
        }
    }

    /// Assemble the layer-0 input (state rows, plus `t` per row under
    /// time-concat) into `a0`.
    fn assemble_input(&self, ts: &[f64], x: &[f32], batch: usize, a0: &mut [f32]) {
        let in_feat = self.dims[0];
        match self.time {
            TimeMode::Concat => {
                for b in 0..batch {
                    a0[b * in_feat..b * in_feat + self.n_state]
                        .copy_from_slice(&x[b * self.n_state..(b + 1) * self.n_state]);
                    a0[b * in_feat + self.n_state] = ts[b] as f32;
                }
            }
            _ => a0.copy_from_slice(x),
        }
    }

    /// One layer forward: `dst = src @ W_l + b_l` (+ `t·tw` on layer 0
    /// under time-affine), tanh unless `last`.
    #[allow(clippy::too_many_arguments)]
    fn layer_forward(&self, l: usize, ts: &[f64], batch: usize, src: &[f32], dst: &mut [f32]) {
        let (ind, outd) = (self.dims[l], self.dims[l + 1]);
        let w = &self.theta[self.w_off[l]..self.w_off[l] + ind * outd];
        let bias = &self.theta[self.b_off[l]..self.b_off[l] + outd];
        matmul_into(src, w, batch, ind, outd, dst);
        for b in 0..batch {
            axpy(1.0, bias, &mut dst[b * outd..(b + 1) * outd]);
        }
        if l == 0 && self.time == TimeMode::Affine {
            let tw = &self.theta[self.tw_off..self.tw_off + outd];
            for b in 0..batch {
                axpy(ts[b] as f32, tw, &mut dst[b * outd..(b + 1) * outd]);
            }
        }
        if l < self.dims.len() - 2 {
            for v in dst.iter_mut() {
                *v = v.tanh();
            }
        }
    }
}

impl NativeLayered for MlpDynamics {
    fn n_state(&self) -> usize {
        self.n_state
    }

    fn n_params(&self) -> usize {
        self.theta.len()
    }

    fn theta_ref(&self) -> &[f32] {
        &self.theta
    }

    fn set_theta(&mut self, theta: &[f32]) {
        self.theta.copy_from_slice(theta);
        self.rebuild_wt();
    }

    fn counters_ref(&self) -> &EvalCounters {
        &self.counters
    }

    fn pool_ref(&self) -> &ScratchPool {
        &self.pool
    }

    fn nf_depth(&self) -> usize {
        self.dims.len() - 1
    }

    fn forward_core(
        &self,
        ts: &[f64],
        x: &[f32],
        batch: usize,
        s: &mut LayerScratch,
        out: &mut [f32],
    ) {
        let layers = self.dims.len() - 1;
        ensure_layers(&mut s.acts, &self.dims[..layers], batch);
        self.assemble_input(ts, x, batch, &mut s.acts[0]);
        for l in 0..layers {
            let last = l == layers - 1;
            let (head, tail) = s.acts.split_at_mut(l + 1);
            let src: &[f32] = &head[l];
            let dst: &mut [f32] = if last { &mut out[..] } else { &mut tail[0][..] };
            self.layer_forward(l, ts, batch, src, dst);
        }
    }

    fn vjp_core(
        &self,
        ts: &[f64],
        x: &[f32],
        a: &[f32],
        batch: usize,
        s: &mut LayerScratch,
        ax: &mut [f32],
        ath_acc: &mut [f32],
    ) {
        let layers = self.dims.len() - 1;
        // forward staging pass: the inputs to every layer (the last
        // layer's own matmul is skipped — its output is not needed)
        ensure_layers(&mut s.acts, &self.dims[..layers], batch);
        self.assemble_input(ts, x, batch, &mut s.acts[0]);
        for l in 0..layers - 1 {
            let (head, tail) = s.acts.split_at_mut(l + 1);
            let src: &[f32] = &head[l];
            self.layer_forward(l, ts, batch, src, &mut tail[0][..]);
        }
        // backward walk: `d_pre` is the cotangent on layer l's
        // pre-activation (for the last, linear layer that is `a` itself)
        let LayerScratch {
            acts, ca, cb, xt, dw, ..
        } = s;
        let mut cur: &mut Vec<f32> = ca;
        let mut nxt: &mut Vec<f32> = cb;
        for l in (0..layers).rev() {
            let (ind, outd) = (self.dims[l], self.dims[l + 1]);
            let d_pre: &[f32] = if l == layers - 1 { a } else { &cur[..] };
            // d_b += column-sum over rows
            {
                let b_acc = &mut ath_acc[self.b_off[l]..self.b_off[l] + outd];
                for b in 0..batch {
                    axpy(1.0, &d_pre[b * outd..(b + 1) * outd], b_acc);
                }
            }
            if l == 0 && self.time == TimeMode::Affine {
                let tw_acc = &mut ath_acc[self.tw_off..self.tw_off + outd];
                for b in 0..batch {
                    axpy(ts[b] as f32, &d_pre[b * outd..(b + 1) * outd], tw_acc);
                }
            }
            // d_W += actsᵀ · d_pre  (via transposed-activation scratch; the
            // matmul zero-fills `dw`, one axpy preserves the += contract)
            {
                let src = &acts[l][..batch * ind];
                ensure(xt, ind * batch);
                for b in 0..batch {
                    for i in 0..ind {
                        xt[i * batch + b] = src[b * ind + i];
                    }
                }
                ensure(dw, ind * outd);
                matmul_into(xt, d_pre, ind, batch, outd, dw);
                axpy(
                    1.0,
                    &dw[..ind * outd],
                    &mut ath_acc[self.w_off[l]..self.w_off[l] + ind * outd],
                );
            }
            // d_x = d_pre · Wᵀ (cached transpose)
            ensure(nxt, batch * ind);
            matmul_into(d_pre, &self.wt[l], batch, outd, ind, nxt);
            if l > 0 {
                // through tanh: d_pre_{l-1} = d_x ⊙ (1 − act²)
                for (dv, &act) in nxt.iter_mut().zip(&acts[l]) {
                    *dv *= 1.0 - act * act;
                }
                std::mem::swap(&mut cur, &mut nxt);
            } else {
                match self.time {
                    TimeMode::Concat => {
                        let in_feat = self.dims[0];
                        for b in 0..batch {
                            ax[b * self.n_state..(b + 1) * self.n_state].copy_from_slice(
                                &nxt[b * in_feat..b * in_feat + self.n_state],
                            );
                        }
                    }
                    _ => ax.copy_from_slice(&nxt[..batch * self.n_state]),
                }
            }
        }
    }
}

impl_dynamics_via_native_layered!(MlpDynamics);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::batch::BatchSpec;
    use crate::solvers::dynamics::Dynamics;

    fn fd_check(dyn_: &mut MlpDynamics, seed: u64) {
        let mut rng = Rng::new(seed);
        let n = Dynamics::dim(dyn_);
        let mut z = vec![0.0f32; n];
        rng.fill_uniform_sym(&mut z, 0.8);
        let mut a = vec![0.0f32; n];
        rng.fill_uniform_sym(&mut a, 1.0);
        let t = 0.37;
        let (az, ath) = dyn_.f_vjp(t, &z, &a);
        let eps = 1e-3;
        // d/dz
        for j in 0..n {
            let mut zp = z.clone();
            zp[j] += eps as f32;
            let mut zm = z.clone();
            zm[j] -= eps as f32;
            let fp = dyn_.f(t, &zp);
            let fm = dyn_.f(t, &zm);
            let fd: f64 = fp
                .iter()
                .zip(&fm)
                .zip(&a)
                .map(|((&p, &m), &ai)| ((p - m) as f64 / (2.0 * eps)) * ai as f64)
                .sum();
            assert!(
                (fd - az[j] as f64).abs() < 5e-3,
                "a_z[{j}]: fd {fd} vs {}",
                az[j]
            );
        }
        // d/dθ on a spread of coordinates (covers W, b, and tw/concat col)
        let theta0 = dyn_.params().to_vec();
        let p = theta0.len();
        for &k in &[0usize, p / 3, p / 2, 2 * p / 3, p - 1] {
            let mut tp = theta0.clone();
            tp[k] += eps as f32;
            dyn_.set_params(&tp);
            let fp = dyn_.f(t, &z);
            let mut tm = theta0.clone();
            tm[k] -= eps as f32;
            dyn_.set_params(&tm);
            let fm = dyn_.f(t, &z);
            dyn_.set_params(&theta0);
            let fd: f64 = fp
                .iter()
                .zip(&fm)
                .zip(&a)
                .map(|((&p_, &m), &ai)| ((p_ - m) as f64 / (2.0 * eps)) * ai as f64)
                .sum();
            assert!(
                (fd - ath[k] as f64).abs() < 5e-3,
                "a_θ[{k}]: fd {fd} vs {}",
                ath[k]
            );
        }
    }

    /// Hand-written matmul vjp matches central finite differences for
    /// every time-conditioning mode and a deep stack.
    #[test]
    fn vjp_matches_finite_differences_all_time_modes() {
        for (seed, time) in [
            (31u64, TimeMode::None),
            (32, TimeMode::Concat),
            (33, TimeMode::Affine),
        ] {
            let mut rng = Rng::new(seed);
            let mut dyn_ = MlpDynamics::new(4, &[6, 5], time, &mut rng);
            fd_check(&mut dyn_, seed ^ 0xF00D);
        }
        // single affine layer (no hidden) and a deeper stack
        let mut rng = Rng::new(41);
        let mut shallow = MlpDynamics::new(3, &[], TimeMode::Concat, &mut rng);
        fd_check(&mut shallow, 42);
        let mut deep = MlpDynamics::new(3, &[5, 7, 4], TimeMode::Affine, &mut rng);
        fd_check(&mut deep, 43);
    }

    /// The batched forward/vjp must agree with the solo entry points
    /// row-for-row — bitwise for `f` and `a_z` (matmul rows are
    /// independent), tolerance for the θ-sum (different but equally valid
    /// accumulation order).
    #[test]
    fn batch_matches_solo_rows() {
        let mut rng = Rng::new(7);
        let dyn_ = MlpDynamics::new(5, &[9], TimeMode::Concat, &mut rng);
        let spec = BatchSpec::new(4, 5);
        let mut z = vec![0.0f32; spec.flat_len()];
        rng.fill_uniform_sym(&mut z, 0.7);
        let ts = [0.0, 0.4, 0.9, 1.3];
        let fb = dyn_.f_batch(&ts, &z, &spec);
        for (b, &t) in ts.iter().enumerate() {
            assert_eq!(
                spec.row(&fb, b),
                dyn_.f(t, spec.row(&z, b)).as_slice(),
                "f row {b}"
            );
        }
        let mut a = vec![0.0f32; spec.flat_len()];
        rng.fill_uniform_sym(&mut a, 1.0);
        let (azb, athb) = dyn_.f_vjp_batch(&ts, &z, &a, &spec);
        let mut ath_sum = vec![0.0f32; dyn_.param_dim()];
        for (b, &t) in ts.iter().enumerate() {
            let (az, ath) = dyn_.f_vjp(t, spec.row(&z, b), spec.row(&a, b));
            assert_eq!(spec.row(&azb, b), az.as_slice(), "a_z row {b}");
            crate::tensor::axpy(1.0, &ath, &mut ath_sum);
        }
        for (k, (&got, &want)) in athb.iter().zip(&ath_sum).enumerate() {
            assert!(
                (got - want).abs() < 1e-4 * (1.0 + want.abs()),
                "a_θ[{k}]: {got} vs {want}"
            );
        }
    }

    /// `set_params` must rebuild the cached `Wᵀ`: a stale transpose would
    /// silently corrupt every subsequent vjp.
    #[test]
    fn set_params_invalidates_transpose_cache() {
        let mut rng = Rng::new(17);
        let mut dyn_ = MlpDynamics::new(3, &[4], TimeMode::None, &mut rng);
        let z = [0.2f32, -0.5, 0.8];
        let a = [1.0f32, 0.5, -0.25];
        let (az0, _) = dyn_.f_vjp(0.0, &z, &a);
        let mut theta = dyn_.params().to_vec();
        for v in theta.iter_mut() {
            *v *= -1.3;
        }
        dyn_.set_params(&theta);
        let (az1, _) = dyn_.f_vjp(0.0, &z, &a);
        assert_ne!(az0, az1, "vjp must see the new θ");
        // round-trip back: bitwise restoration proves the cache is purely
        // θ-derived state
        for v in theta.iter_mut() {
            *v /= -1.3;
        }
        dyn_.set_params(&theta);
        let (az2, _) = dyn_.f_vjp(0.0, &z, &a);
        assert_eq!(az0, az2);
    }

    /// Counter accounting: per-sample units on every entry point, fused
    /// hooks included (ψ ≡ 1 f-unit, ψ-vjp ≡ 1 vjp-unit per row).
    #[test]
    fn counters_count_per_sample_units() {
        let mut rng = Rng::new(23);
        let dyn_ = MlpDynamics::new(3, &[4], TimeMode::None, &mut rng);
        let spec = BatchSpec::new(5, 3);
        let mut z = vec![0.0f32; spec.flat_len()];
        rng.fill_uniform_sym(&mut z, 0.5);
        let ts = [0.0; 5];
        dyn_.f(0.0, &z[..3]);
        dyn_.f_batch(&ts, &z, &spec);
        assert_eq!(dyn_.counters().f_evals.get(), 1 + 5);
        let a = vec![1.0f32; spec.flat_len()];
        dyn_.f_vjp(0.0, &z[..3], &a[..3]);
        dyn_.f_vjp_batch(&ts, &z, &a, &spec);
        assert_eq!(dyn_.counters().vjp_evals.get(), 1 + 5);
        dyn_.counters().reset();
        // fused ψ counts like one composed f per row; fused bwd one f + one vjp
        let v = dyn_.f(0.0, &z[..3]);
        dyn_.counters().reset();
        let (z1, v1, _) = dyn_.fused_alf(&z[..3], &v, 0.0, 0.1, 1.0).unwrap();
        assert_eq!(dyn_.counters().f_evals.get(), 1);
        dyn_.fused_alf_bwd(&z1, &v1, 0.1, 0.1, 1.0, &a[..3], &a[..3])
            .unwrap();
        assert_eq!(dyn_.counters().f_evals.get(), 2);
        assert_eq!(dyn_.counters().vjp_evals.get(), 1);
    }
}
