//! Native layered dynamics: deep MLP and conv-stem right-hand sides whose
//! forward **and** vjp ride the `tensor` dispatch kernels (`matmul_into`),
//! plus fused ALF entry points that execute one ψ / ψ⁻¹ / ψ-vjp step as a
//! single pass over the layer stack.
//!
//! This is the host-side port of the L1 Pallas kernel math
//! (`python/compile/kernels/alf_step.py`, oracle `kernels/ref.py`): where
//! `runtime::HloDynamics` dispatches a compiled device graph per call, the
//! backends here lower every layer onto `tensor::matmul_into` so the whole
//! stack is matmul-bound — the regime the paper's ImageNet numbers live in —
//! while staying tier-1 testable with no artifacts and no PJRT.
//!
//! ## Architecture
//!
//! [`NativeLayered`] is the internal contract: a layer stack exposing one
//! batched `forward_core` and one batched `vjp_core` over caller scratch.
//! Everything else — the full [`Dynamics`] surface (solo/batch, allocating/
//! `_into`) and the seven fused ALF hooks — is implemented **once** by the
//! free functions in this module and stamped onto each backend by
//! `impl_dynamics_via_native_layered!`.  Adding a new native backend means
//! implementing `forward_core`/`vjp_core` and nothing more.
//!
//! ## Fused-dynamics contract (DESIGN.md §9)
//!
//! * Scratch comes from a [`ScratchPool`] owned by the dynamics: workers
//!   pop a warm [`LayerScratch`] per call and push it back when done, so
//!   concurrent shard workers never serialize on buffers and a warmed
//!   steady state performs **zero heap allocations** (pinned by
//!   `tests/alloc_steady.rs`).
//! * The vjp needs `Wᵀ` per layer (`d_x = d_pre · Wᵀ`); those transposes
//!   are cached on the struct and rebuilt inside `set_params` — the only
//!   place θ can change — so they can never go stale.
//! * Fused steps replicate the solver's composed arithmetic **bitwise**
//!   (same kernel call sequence, same f32 cast order; verified in
//!   `tests/prop_solver.rs`) and count the same per-sample
//!   [`EvalCounters`] units as the unfused path (fused ψ ≡ one `f` unit
//!   per row, fused ψ-vjp ≡ one vjp unit per row, the fused backward
//!   micro-step ≡ one of each), keeping the Table-1 cost laws and the
//!   shard-invariance suite honest.

pub mod conv;
pub mod mlp;

pub use conv::ConvStemDynamics;
pub use mlp::{MlpDynamics, TimeMode};

use crate::solvers::batch::BatchSpec;
use crate::solvers::dynamics::EvalCounters;
use crate::solvers::workspace::{ensure, fill_row_coeffs, fill_stage_times};
use crate::tensor::{add_scaled_into, add_scaled_rows_into, axpy};
use std::sync::Mutex;

/// Upper bound on pooled scratch instances (one per concurrent caller is
/// enough; extras beyond this are dropped instead of hoarded).
const POOL_CAP: usize = 32;

/// Per-call scratch for one layered forward/vjp or one fused ALF step.
/// Buffers are grown with [`ensure`] on use and reused verbatim when the
/// shapes repeat — the warm steady state never touches the allocator.
#[derive(Debug, Default)]
pub struct LayerScratch {
    /// Per-layer forward buffers: `acts[0]` is the assembled input
    /// (time-concat appends `t` per row), `acts[l]` for `l ≥ 1` the
    /// activation output of layer `l-1`, each `[batch, dims[l]]`.
    pub(crate) acts: Vec<Vec<f32>>,
    /// Per-layer im2col buffers (conv backends only).
    pub(crate) cols: Vec<Vec<f32>>,
    /// Cotangent ping-pong buffers (backward walks the stack once).
    pub(crate) ca: Vec<f32>,
    pub(crate) cb: Vec<f32>,
    /// Transposed-activation scratch for `d_W = Xᵀ · d_pre`.
    pub(crate) xt: Vec<f32>,
    /// Per-layer `d_W` staging (`matmul_into` zero-fills, then one axpy
    /// accumulates into the caller's `ath_acc` to honour the `+=` contract).
    pub(crate) dw: Vec<f32>,
    /// `d_cols` staging for the conv backward (before col2im scatter).
    pub(crate) dcols: Vec<f32>,
    // ---- fused-step state buffers (all `[B·n_z]`) ----------------------
    pub(crate) k1: Vec<f32>,
    pub(crate) u1: Vec<f32>,
    pub(crate) g: Vec<f32>,
    pub(crate) av_tot: Vec<f32>,
    pub(crate) a_u1: Vec<f32>,
    /// Per-row `h/2` coefficients and stage times for batched fused steps.
    pub(crate) half: Vec<f32>,
    pub(crate) s1s: Vec<f64>,
}

/// Lock-guarded stack of warm [`LayerScratch`] instances.  `acquire` pops
/// (allocating only when the pool is cold), `release` pushes back; the
/// `Mutex` is held only for the pop/push, so shard workers overlap their
/// actual compute freely.
#[derive(Debug, Default)]
pub struct ScratchPool {
    slots: Mutex<Vec<Box<LayerScratch>>>,
}

impl ScratchPool {
    pub fn new() -> Self {
        ScratchPool {
            slots: Mutex::new(Vec::with_capacity(POOL_CAP)),
        }
    }

    pub(crate) fn acquire(&self) -> Box<LayerScratch> {
        self.slots
            .lock()
            .expect("scratch pool poisoned")
            .pop()
            .unwrap_or_default()
    }

    pub(crate) fn release(&self, s: Box<LayerScratch>) {
        let mut slots = self.slots.lock().expect("scratch pool poisoned");
        if slots.len() < POOL_CAP {
            slots.push(s);
        }
    }
}

/// Shape `bufs` to exactly `sizes.len()` buffers of `batch · sizes[l]`
/// elements each (grow-once; warm calls are allocation-free).
pub(crate) fn ensure_layers(bufs: &mut Vec<Vec<f32>>, sizes: &[usize], batch: usize) {
    while bufs.len() < sizes.len() {
        bufs.push(Vec::new());
    }
    for (b, &n) in bufs.iter_mut().zip(sizes) {
        ensure(b, batch * n);
    }
}

/// The internal layer-stack contract every native backend implements; the
/// full [`crate::solvers::dynamics::Dynamics`] surface plus all fused ALF
/// hooks are derived from these two cores by the `nl_*` functions below.
pub(crate) trait NativeLayered: Send + Sync {
    /// Flattened per-sample state dimension.
    fn n_state(&self) -> usize;
    /// Flattened θ dimension.
    fn n_params(&self) -> usize;
    /// The flat parameter vector.
    fn theta_ref(&self) -> &[f32];
    /// Replace θ and rebuild every θ-derived cache (`Wᵀ`).
    fn set_theta(&mut self, theta: &[f32]);
    fn counters_ref(&self) -> &EvalCounters;
    fn pool_ref(&self) -> &ScratchPool;
    /// Layer count, for Table-1 N_f accounting.
    fn nf_depth(&self) -> usize;
    /// Batched forward over `[batch, n_state]` rows with per-row times.
    /// Must be row-decomposable bitwise (row `b` of `out` depends only on
    /// row `b` of `x` and `ts[b]`) — the shard-invariance suite relies on
    /// it.  Does **not** touch counters; the `nl_*` wrappers count.
    fn forward_core(
        &self,
        ts: &[f64],
        x: &[f32],
        batch: usize,
        s: &mut LayerScratch,
        out: &mut [f32],
    );
    /// Batched vjp: `ax` is overwritten with `aᵀ ∂f/∂x` (row-decomposable
    /// bitwise), the row-summed θ-cotangent is **accumulated** into
    /// `ath_acc` (`+=`).  Runs its own forward to stage activations.
    #[allow(clippy::too_many_arguments)]
    fn vjp_core(
        &self,
        ts: &[f64],
        x: &[f32],
        a: &[f32],
        batch: usize,
        s: &mut LayerScratch,
        ax: &mut [f32],
        ath_acc: &mut [f32],
    );
}

// ---------------------------------------------------------------------------
// Dynamics-surface helpers (generic over the backend)
// ---------------------------------------------------------------------------

pub(crate) fn nl_f_into<M: NativeLayered>(m: &M, t: f64, z: &[f32], out: &mut [f32]) {
    m.counters_ref().f_evals.add(1);
    let mut s = m.pool_ref().acquire();
    m.forward_core(&[t], z, 1, &mut s, out);
    m.pool_ref().release(s);
}

pub(crate) fn nl_f_vjp_into<M: NativeLayered>(
    m: &M,
    t: f64,
    z: &[f32],
    a: &[f32],
    az_out: &mut [f32],
    ath_acc: &mut [f32],
) {
    m.counters_ref().vjp_evals.add(1);
    let mut s = m.pool_ref().acquire();
    m.vjp_core(&[t], z, a, 1, &mut s, az_out, ath_acc);
    m.pool_ref().release(s);
}

pub(crate) fn nl_f_batch_into<M: NativeLayered>(
    m: &M,
    ts: &[f64],
    z: &[f32],
    spec: &BatchSpec,
    out: &mut [f32],
) {
    debug_assert_eq!(ts.len(), spec.batch);
    debug_assert_eq!(z.len(), spec.flat_len());
    m.counters_ref().f_evals.add(spec.batch as u64);
    let mut s = m.pool_ref().acquire();
    m.forward_core(ts, z, spec.batch, &mut s, out);
    m.pool_ref().release(s);
}

pub(crate) fn nl_f_vjp_batch_into<M: NativeLayered>(
    m: &M,
    ts: &[f64],
    z: &[f32],
    a: &[f32],
    spec: &BatchSpec,
    az_out: &mut [f32],
    ath_acc: &mut [f32],
) {
    debug_assert_eq!(ts.len(), spec.batch);
    m.counters_ref().vjp_evals.add(spec.batch as u64);
    let mut s = m.pool_ref().acquire();
    m.vjp_core(ts, z, a, spec.batch, &mut s, az_out, ath_acc);
    m.pool_ref().release(s);
}

// ---------------------------------------------------------------------------
// Fused ALF steps — one scratch acquisition, one pass over the layer stack,
// no intermediate `State` copies.  Each replicates the *exact* kernel call
// sequence of the corresponding composed solver path (`solvers::alf`), so
// fused ≡ unfused bitwise.
// ---------------------------------------------------------------------------

/// Fused ψ (mirrors `AlfSolver::psi_into`'s composed arithmetic).
#[allow(clippy::too_many_arguments)]
pub(crate) fn nl_fused_psi<M: NativeLayered>(
    m: &M,
    z: &[f32],
    v: &[f32],
    t: f64,
    h: f64,
    eta: f64,
    z_out: &mut [f32],
    v_out: &mut [f32],
    err_out: &mut [f32],
) {
    m.counters_ref().f_evals.add(1);
    let mut s = m.pool_ref().acquire();
    let etaf = eta as f32;
    let hf = h as f32;
    let s1 = t + h / 2.0;
    let n = z.len();
    let mut k1 = std::mem::take(&mut s.k1);
    ensure(&mut k1, n);
    add_scaled_into(z, hf / 2.0, v, &mut k1);
    let mut u1 = std::mem::take(&mut s.u1);
    ensure(&mut u1, n);
    m.forward_core(&[s1], &k1, 1, &mut s, &mut u1);
    v_out.fill(0.0);
    axpy(1.0 - 2.0 * etaf, v, v_out);
    axpy(2.0 * etaf, &u1, v_out);
    add_scaled_into(&k1, hf / 2.0, v_out, z_out);
    for ((e, &u), &vi) in err_out.iter_mut().zip(u1.iter()).zip(v) {
        *e = etaf * hf * (u - vi);
    }
    s.k1 = k1;
    s.u1 = u1;
    m.pool_ref().release(s);
}

/// Fused ψ⁻¹ (mirrors `AlfSolver::psi_inv_into`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn nl_fused_psi_inv<M: NativeLayered>(
    m: &M,
    z_out: &[f32],
    v_out: &[f32],
    t_out: f64,
    h: f64,
    eta: f64,
    z_in: &mut [f32],
    v_in: &mut [f32],
) {
    m.counters_ref().f_evals.add(1);
    let mut s = m.pool_ref().acquire();
    let etaf = eta as f32;
    let hf = h as f32;
    let s1 = t_out - h / 2.0;
    let n = z_out.len();
    let mut k1 = std::mem::take(&mut s.k1);
    ensure(&mut k1, n);
    add_scaled_into(z_out, -hf / 2.0, v_out, &mut k1);
    let mut u1 = std::mem::take(&mut s.u1);
    ensure(&mut u1, n);
    m.forward_core(&[s1], &k1, 1, &mut s, &mut u1);
    let denom = 1.0 - 2.0 * etaf;
    for ((vi, &vo), &u) in v_in.iter_mut().zip(v_out).zip(u1.iter()) {
        *vi = (vo - 2.0 * etaf * u) / denom;
    }
    add_scaled_into(&k1, -hf / 2.0, v_in, z_in);
    s.k1 = k1;
    s.u1 = u1;
    m.pool_ref().release(s);
}

/// Fused ψ-vjp (mirrors `AlfSolver::psi_vjp_into`; θ-cotangent `+=`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn nl_fused_psi_vjp<M: NativeLayered>(
    m: &M,
    z: &[f32],
    v: &[f32],
    t: f64,
    h: f64,
    eta: f64,
    az_out: &[f32],
    av_out: &[f32],
    az_in: &mut [f32],
    av_in: &mut [f32],
    ath_acc: &mut [f32],
) {
    m.counters_ref().vjp_evals.add(1);
    let mut s = m.pool_ref().acquire();
    let etaf = eta as f32;
    let hf = h as f32;
    let s1 = t + h / 2.0;
    let n = z.len();
    let mut k1 = std::mem::take(&mut s.k1);
    ensure(&mut k1, n);
    add_scaled_into(z, hf / 2.0, v, &mut k1);
    let mut av_tot = std::mem::take(&mut s.av_tot);
    ensure(&mut av_tot, n);
    add_scaled_into(av_out, hf / 2.0, az_out, &mut av_tot);
    for (o, &x) in av_in.iter_mut().zip(av_tot.iter()) {
        *o = (1.0 - 2.0 * etaf) * x;
    }
    let mut a_u1 = std::mem::take(&mut s.a_u1);
    ensure(&mut a_u1, n);
    for (o, &x) in a_u1.iter_mut().zip(av_tot.iter()) {
        *o = 2.0 * etaf * x;
    }
    let mut g = std::mem::take(&mut s.g);
    ensure(&mut g, n);
    m.vjp_core(&[s1], &k1, &a_u1, 1, &mut s, &mut g, ath_acc);
    add_scaled_into(az_out, 1.0, &g, az_in);
    axpy(hf / 2.0, az_in, av_in);
    s.k1 = k1;
    s.av_tot = av_tot;
    s.a_u1 = a_u1;
    s.g = g;
    m.pool_ref().release(s);
}

/// Fused MALI backward micro-step: ψ⁻¹ reconstruction *and* the vjp
/// through ψ at the reconstructed point in one pass (mirrors the
/// host-composed `invert_into` + `step_vjp_into(t_out − h, ..)` fallback
/// exactly, including the recomputed `k1 = z_in + (h/2)·v_in` — f32
/// `(a−b)+b ≠ a`, so reusing ψ⁻¹'s `k1` would break bitwise equality).
#[allow(clippy::too_many_arguments)]
pub(crate) fn nl_fused_bwd<M: NativeLayered>(
    m: &M,
    z_out: &[f32],
    v_out: &[f32],
    t_out: f64,
    h: f64,
    eta: f64,
    az_out: &[f32],
    av_out: &[f32],
    z_in: &mut [f32],
    v_in: &mut [f32],
    az_in: &mut [f32],
    av_in: &mut [f32],
    ath_acc: &mut [f32],
) {
    m.counters_ref().f_evals.add(1);
    m.counters_ref().vjp_evals.add(1);
    let mut s = m.pool_ref().acquire();
    let etaf = eta as f32;
    let hf = h as f32;
    let n = z_out.len();
    // ---- ψ⁻¹ ----
    let s1_inv = t_out - h / 2.0;
    let mut k1 = std::mem::take(&mut s.k1);
    ensure(&mut k1, n);
    add_scaled_into(z_out, -hf / 2.0, v_out, &mut k1);
    let mut u1 = std::mem::take(&mut s.u1);
    ensure(&mut u1, n);
    m.forward_core(&[s1_inv], &k1, 1, &mut s, &mut u1);
    let denom = 1.0 - 2.0 * etaf;
    for ((vi, &vo), &u) in v_in.iter_mut().zip(v_out).zip(u1.iter()) {
        *vi = (vo - 2.0 * etaf * u) / denom;
    }
    add_scaled_into(&k1, -hf / 2.0, v_in, z_in);
    // ---- vjp through ψ at (t_out − h) ----
    let s1_vjp = (t_out - h) + h / 2.0;
    add_scaled_into(z_in, hf / 2.0, v_in, &mut k1);
    let mut av_tot = std::mem::take(&mut s.av_tot);
    ensure(&mut av_tot, n);
    add_scaled_into(av_out, hf / 2.0, az_out, &mut av_tot);
    for (o, &x) in av_in.iter_mut().zip(av_tot.iter()) {
        *o = (1.0 - 2.0 * etaf) * x;
    }
    let mut a_u1 = std::mem::take(&mut s.a_u1);
    ensure(&mut a_u1, n);
    for (o, &x) in a_u1.iter_mut().zip(av_tot.iter()) {
        *o = 2.0 * etaf * x;
    }
    let mut g = std::mem::take(&mut s.g);
    ensure(&mut g, n);
    m.vjp_core(&[s1_vjp], &k1, &a_u1, 1, &mut s, &mut g, ath_acc);
    add_scaled_into(az_out, 1.0, &g, az_in);
    axpy(hf / 2.0, az_in, av_in);
    s.k1 = k1;
    s.u1 = u1;
    s.av_tot = av_tot;
    s.a_u1 = a_u1;
    s.g = g;
    m.pool_ref().release(s);
}

/// Batched fused ψ (mirrors `AlfSolver::psi_batch_into`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn nl_fused_psi_batch<M: NativeLayered>(
    m: &M,
    ts: &[f64],
    hs: &[f64],
    z: &[f32],
    v: &[f32],
    eta: f64,
    spec: &BatchSpec,
    z_out: &mut [f32],
    v_out: &mut [f32],
    err_out: &mut [f32],
) {
    m.counters_ref().f_evals.add(spec.batch as u64);
    let mut s = m.pool_ref().acquire();
    let etaf = eta as f32;
    let n = spec.flat_len();
    let mut half = std::mem::take(&mut s.half);
    let mut s1s = std::mem::take(&mut s.s1s);
    fill_row_coeffs(hs, 0.5, &mut half);
    fill_stage_times(ts, hs, 0.5, &mut s1s);
    let mut k1 = std::mem::take(&mut s.k1);
    ensure(&mut k1, n);
    add_scaled_rows_into(z, &half, v, spec.n_z, &mut k1);
    let mut u1 = std::mem::take(&mut s.u1);
    ensure(&mut u1, n);
    m.forward_core(&s1s, &k1, spec.batch, &mut s, &mut u1);
    v_out.fill(0.0);
    axpy(1.0 - 2.0 * etaf, v, v_out);
    axpy(2.0 * etaf, &u1, v_out);
    add_scaled_rows_into(&k1, &half, v_out, spec.n_z, z_out);
    for b in 0..spec.batch {
        let hf = hs[b] as f32;
        let lo = b * spec.n_z;
        let hi = lo + spec.n_z;
        for ((e, &u), &vi) in err_out[lo..hi]
            .iter_mut()
            .zip(&u1[lo..hi])
            .zip(&v[lo..hi])
        {
            *e = etaf * hf * (u - vi);
        }
    }
    s.half = half;
    s.s1s = s1s;
    s.k1 = k1;
    s.u1 = u1;
    m.pool_ref().release(s);
}

/// Batched fused ψ⁻¹ (mirrors `AlfSolver::psi_inv_batch_into`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn nl_fused_psi_inv_batch<M: NativeLayered>(
    m: &M,
    ts_out: &[f64],
    hs: &[f64],
    z_out: &[f32],
    v_out: &[f32],
    eta: f64,
    spec: &BatchSpec,
    z_in: &mut [f32],
    v_in: &mut [f32],
) {
    m.counters_ref().f_evals.add(spec.batch as u64);
    let mut s = m.pool_ref().acquire();
    let etaf = eta as f32;
    let n = spec.flat_len();
    let mut half = std::mem::take(&mut s.half);
    let mut s1s = std::mem::take(&mut s.s1s);
    fill_row_coeffs(hs, -0.5, &mut half);
    fill_stage_times(ts_out, hs, -0.5, &mut s1s);
    let mut k1 = std::mem::take(&mut s.k1);
    ensure(&mut k1, n);
    add_scaled_rows_into(z_out, &half, v_out, spec.n_z, &mut k1);
    let mut u1 = std::mem::take(&mut s.u1);
    ensure(&mut u1, n);
    m.forward_core(&s1s, &k1, spec.batch, &mut s, &mut u1);
    let denom = 1.0 - 2.0 * etaf;
    for ((vi, &vo), &u) in v_in.iter_mut().zip(v_out).zip(u1.iter()) {
        *vi = (vo - 2.0 * etaf * u) / denom;
    }
    add_scaled_rows_into(&k1, &half, v_in, spec.n_z, z_in);
    s.half = half;
    s.s1s = s1s;
    s.k1 = k1;
    s.u1 = u1;
    m.pool_ref().release(s);
}

/// Batched fused ψ-vjp (mirrors `AlfSolver::psi_vjp_batch_into`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn nl_fused_psi_vjp_batch<M: NativeLayered>(
    m: &M,
    ts: &[f64],
    hs: &[f64],
    z: &[f32],
    v: &[f32],
    eta: f64,
    spec: &BatchSpec,
    az_out: &[f32],
    av_out: &[f32],
    az_in: &mut [f32],
    av_in: &mut [f32],
    ath_acc: &mut [f32],
) {
    m.counters_ref().vjp_evals.add(spec.batch as u64);
    let mut s = m.pool_ref().acquire();
    let etaf = eta as f32;
    let n = spec.flat_len();
    let mut half = std::mem::take(&mut s.half);
    let mut s1s = std::mem::take(&mut s.s1s);
    fill_row_coeffs(hs, 0.5, &mut half);
    fill_stage_times(ts, hs, 0.5, &mut s1s);
    let mut k1 = std::mem::take(&mut s.k1);
    ensure(&mut k1, n);
    add_scaled_rows_into(z, &half, v, spec.n_z, &mut k1);
    let mut av_tot = std::mem::take(&mut s.av_tot);
    ensure(&mut av_tot, n);
    add_scaled_rows_into(av_out, &half, az_out, spec.n_z, &mut av_tot);
    for (o, &x) in av_in.iter_mut().zip(av_tot.iter()) {
        *o = (1.0 - 2.0 * etaf) * x;
    }
    let mut a_u1 = std::mem::take(&mut s.a_u1);
    ensure(&mut a_u1, n);
    for (o, &x) in a_u1.iter_mut().zip(av_tot.iter()) {
        *o = 2.0 * etaf * x;
    }
    let mut g = std::mem::take(&mut s.g);
    ensure(&mut g, n);
    m.vjp_core(&s1s, &k1, &a_u1, spec.batch, &mut s, &mut g, ath_acc);
    add_scaled_into(az_out, 1.0, &g, az_in);
    crate::tensor::axpy_rows(&half, az_in, av_in, spec.n_z);
    s.half = half;
    s.s1s = s1s;
    s.k1 = k1;
    s.av_tot = av_tot;
    s.a_u1 = a_u1;
    s.g = g;
    m.pool_ref().release(s);
}

/// Stamp the full [`crate::solvers::dynamics::Dynamics`] surface — solo,
/// batch, allocating, `_into`, and all fused ALF hooks — onto a backend
/// that implements [`NativeLayered`].
macro_rules! impl_dynamics_via_native_layered {
    ($ty:ty) => {
        impl crate::solvers::dynamics::Dynamics for $ty {
            fn dim(&self) -> usize {
                crate::dynamics_native::NativeLayered::n_state(self)
            }

            fn param_dim(&self) -> usize {
                crate::dynamics_native::NativeLayered::n_params(self)
            }

            fn f(&self, t: f64, z: &[f32]) -> Vec<f32> {
                let mut out = vec![0.0f32; z.len()];
                crate::dynamics_native::nl_f_into(self, t, z, &mut out);
                out
            }

            fn f_vjp(&self, t: f64, z: &[f32], a: &[f32]) -> (Vec<f32>, Vec<f32>) {
                let mut az = vec![0.0f32; z.len()];
                let mut ath =
                    vec![0.0f32; crate::dynamics_native::NativeLayered::n_params(self)];
                crate::dynamics_native::nl_f_vjp_into(self, t, z, a, &mut az, &mut ath);
                (az, ath)
            }

            fn params(&self) -> &[f32] {
                crate::dynamics_native::NativeLayered::theta_ref(self)
            }

            fn set_params(&mut self, theta: &[f32]) {
                crate::dynamics_native::NativeLayered::set_theta(self, theta)
            }

            fn counters(&self) -> &crate::solvers::dynamics::EvalCounters {
                crate::dynamics_native::NativeLayered::counters_ref(self)
            }

            fn depth_nf(&self) -> usize {
                crate::dynamics_native::NativeLayered::nf_depth(self)
            }

            fn f_batch(
                &self,
                ts: &[f64],
                z: &[f32],
                spec: &crate::solvers::batch::BatchSpec,
            ) -> Vec<f32> {
                let mut out = vec![0.0f32; spec.flat_len()];
                crate::dynamics_native::nl_f_batch_into(self, ts, z, spec, &mut out);
                out
            }

            fn f_vjp_batch(
                &self,
                ts: &[f64],
                z: &[f32],
                a: &[f32],
                spec: &crate::solvers::batch::BatchSpec,
            ) -> (Vec<f32>, Vec<f32>) {
                let mut az = vec![0.0f32; spec.flat_len()];
                let mut ath =
                    vec![0.0f32; crate::dynamics_native::NativeLayered::n_params(self)];
                crate::dynamics_native::nl_f_vjp_batch_into(
                    self, ts, z, a, spec, &mut az, &mut ath,
                );
                (az, ath)
            }

            fn f_into(&self, t: f64, z: &[f32], out: &mut [f32]) {
                crate::dynamics_native::nl_f_into(self, t, z, out)
            }

            fn f_vjp_into(
                &self,
                t: f64,
                z: &[f32],
                a: &[f32],
                az_out: &mut [f32],
                ath_acc: &mut [f32],
            ) {
                crate::dynamics_native::nl_f_vjp_into(self, t, z, a, az_out, ath_acc)
            }

            fn f_batch_into(
                &self,
                ts: &[f64],
                z: &[f32],
                spec: &crate::solvers::batch::BatchSpec,
                out: &mut [f32],
            ) {
                crate::dynamics_native::nl_f_batch_into(self, ts, z, spec, out)
            }

            fn f_vjp_batch_into(
                &self,
                ts: &[f64],
                z: &[f32],
                a: &[f32],
                spec: &crate::solvers::batch::BatchSpec,
                az_out: &mut [f32],
                ath_acc: &mut [f32],
            ) {
                crate::dynamics_native::nl_f_vjp_batch_into(
                    self, ts, z, a, spec, az_out, ath_acc,
                )
            }

            // ---- fused ALF hooks (allocating forms wrap the `_into`s) ----

            fn fused_alf(
                &self,
                z: &[f32],
                v: &[f32],
                t: f64,
                h: f64,
                eta: f64,
            ) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>)> {
                let mut z_out = vec![0.0f32; z.len()];
                let mut v_out = vec![0.0f32; v.len()];
                let mut err = vec![0.0f32; v.len()];
                crate::dynamics_native::nl_fused_psi(
                    self, z, v, t, h, eta, &mut z_out, &mut v_out, &mut err,
                );
                Some((z_out, v_out, err))
            }

            fn fused_alf_inv(
                &self,
                z: &[f32],
                v: &[f32],
                t_out: f64,
                h: f64,
                eta: f64,
            ) -> Option<(Vec<f32>, Vec<f32>)> {
                let mut z_in = vec![0.0f32; z.len()];
                let mut v_in = vec![0.0f32; v.len()];
                crate::dynamics_native::nl_fused_psi_inv(
                    self, z, v, t_out, h, eta, &mut z_in, &mut v_in,
                );
                Some((z_in, v_in))
            }

            fn fused_alf_vjp(
                &self,
                z: &[f32],
                v: &[f32],
                t: f64,
                h: f64,
                eta: f64,
                az_out: &[f32],
                av_out: &[f32],
            ) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>)> {
                let mut az_in = vec![0.0f32; z.len()];
                let mut av_in = vec![0.0f32; v.len()];
                let mut ath =
                    vec![0.0f32; crate::dynamics_native::NativeLayered::n_params(self)];
                crate::dynamics_native::nl_fused_psi_vjp(
                    self, z, v, t, h, eta, az_out, av_out, &mut az_in, &mut av_in, &mut ath,
                );
                Some((az_in, av_in, ath))
            }

            fn fused_alf_bwd(
                &self,
                z_out: &[f32],
                v_out: &[f32],
                t_out: f64,
                h: f64,
                eta: f64,
                az_out: &[f32],
                av_out: &[f32],
            ) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
                let n = z_out.len();
                let mut z_in = vec![0.0f32; n];
                let mut v_in = vec![0.0f32; n];
                let mut az_in = vec![0.0f32; n];
                let mut av_in = vec![0.0f32; n];
                let mut ath =
                    vec![0.0f32; crate::dynamics_native::NativeLayered::n_params(self)];
                crate::dynamics_native::nl_fused_bwd(
                    self, z_out, v_out, t_out, h, eta, az_out, av_out, &mut z_in,
                    &mut v_in, &mut az_in, &mut av_in, &mut ath,
                );
                Some((z_in, v_in, az_in, av_in, ath))
            }

            fn fused_alf_into(
                &self,
                z: &[f32],
                v: &[f32],
                t: f64,
                h: f64,
                eta: f64,
                z_out: &mut [f32],
                v_out: &mut [f32],
                err_out: &mut [f32],
            ) -> bool {
                crate::dynamics_native::nl_fused_psi(
                    self, z, v, t, h, eta, z_out, v_out, err_out,
                );
                true
            }

            fn fused_alf_inv_into(
                &self,
                z_out: &[f32],
                v_out: &[f32],
                t_out: f64,
                h: f64,
                eta: f64,
                z_in: &mut [f32],
                v_in: &mut [f32],
            ) -> bool {
                crate::dynamics_native::nl_fused_psi_inv(
                    self, z_out, v_out, t_out, h, eta, z_in, v_in,
                );
                true
            }

            fn fused_alf_vjp_into(
                &self,
                z: &[f32],
                v: &[f32],
                t: f64,
                h: f64,
                eta: f64,
                az_out: &[f32],
                av_out: &[f32],
                az_in: &mut [f32],
                av_in: &mut [f32],
                ath_acc: &mut [f32],
            ) -> bool {
                crate::dynamics_native::nl_fused_psi_vjp(
                    self, z, v, t, h, eta, az_out, av_out, az_in, av_in, ath_acc,
                );
                true
            }

            fn fused_alf_bwd_into(
                &self,
                z_out: &[f32],
                v_out: &[f32],
                t_out: f64,
                h: f64,
                eta: f64,
                az_out: &[f32],
                av_out: &[f32],
                z_in: &mut [f32],
                v_in: &mut [f32],
                az_in: &mut [f32],
                av_in: &mut [f32],
                ath_acc: &mut [f32],
            ) -> bool {
                crate::dynamics_native::nl_fused_bwd(
                    self, z_out, v_out, t_out, h, eta, az_out, av_out, z_in, v_in, az_in,
                    av_in, ath_acc,
                );
                true
            }

            fn fused_alf_batch_into(
                &self,
                ts: &[f64],
                hs: &[f64],
                z: &[f32],
                v: &[f32],
                eta: f64,
                spec: &crate::solvers::batch::BatchSpec,
                z_out: &mut [f32],
                v_out: &mut [f32],
                err_out: &mut [f32],
            ) -> bool {
                crate::dynamics_native::nl_fused_psi_batch(
                    self, ts, hs, z, v, eta, spec, z_out, v_out, err_out,
                );
                true
            }

            fn fused_alf_inv_batch_into(
                &self,
                ts_out: &[f64],
                hs: &[f64],
                z_out: &[f32],
                v_out: &[f32],
                eta: f64,
                spec: &crate::solvers::batch::BatchSpec,
                z_in: &mut [f32],
                v_in: &mut [f32],
            ) -> bool {
                crate::dynamics_native::nl_fused_psi_inv_batch(
                    self, ts_out, hs, z_out, v_out, eta, spec, z_in, v_in,
                );
                true
            }

            fn fused_alf_vjp_batch_into(
                &self,
                ts: &[f64],
                hs: &[f64],
                z: &[f32],
                v: &[f32],
                eta: f64,
                spec: &crate::solvers::batch::BatchSpec,
                az_out: &[f32],
                av_out: &[f32],
                az_in: &mut [f32],
                av_in: &mut [f32],
                ath_acc: &mut [f32],
            ) -> bool {
                crate::dynamics_native::nl_fused_psi_vjp_batch(
                    self, ts, hs, z, v, eta, spec, az_out, av_out, az_in, av_in, ath_acc,
                );
                true
            }
        }
    };
}

pub(crate) use impl_dynamics_via_native_layered;
