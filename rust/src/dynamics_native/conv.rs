//! Conv-stem dynamics: 3×3 same-padding convolution stack lowered through
//! **im2col** so every layer rides `tensor::matmul_into` (ADR-005).
//!
//! State layout per sample is channels-last `[H, W, C]` flattened — the
//! im2col matrix is then `[B·H·W, C_in·9]` and one matmul per layer covers
//! the entire batch, which is exactly the shape the dispatch kernels are
//! fastest at.  The vjp runs the textbook transposes: `d_K = colsᵀ·d_pre`
//! and `d_x = col2im(d_pre · Kᵀ)` with the `Kᵀ` cache rebuilt on
//! `set_params` like the MLP's `Wᵀ`.

use super::{
    ensure_layers, impl_dynamics_via_native_layered, LayerScratch, NativeLayered, ScratchPool,
    TimeMode,
};
use crate::solvers::dynamics::EvalCounters;
use crate::solvers::workspace::ensure;
use crate::tensor::{axpy, matmul_into};
use crate::util::rng::Rng;

/// 3×3 same-padding conv → tanh stack over a `[H, W, C]` channels-last
/// state; the channel chain starts and ends at the state's channel count
/// so the stack is a valid ODE right-hand side.
///
/// θ layout (flat): per layer `K` (`C_in·9 × C_out`, row-major, kernel
/// taps ordered `(ky·3 + kx)·C_in + c`) then `b` (`C_out`), followed by
/// the per-channel time vector `tw` (`C₁`) when [`TimeMode::Affine`].
/// [`TimeMode::Concat`] has no natural image analogue and is rejected.
#[derive(Debug)]
pub struct ConvStemDynamics {
    side: usize,
    /// Channel chain `[C_state, mid…, C_state]`.
    channels: Vec<usize>,
    time: TimeMode,
    theta: Vec<f32>,
    k_off: Vec<usize>,
    b_off: Vec<usize>,
    tw_off: usize,
    /// Cached `Kᵀ` per layer (`C_out × C_in·9`); rebuilt by `set_params`.
    kt: Vec<Vec<f32>>,
    counters: EvalCounters,
    pool: ScratchPool,
}

impl ConvStemDynamics {
    /// Stem over a `side×side×c_state` state with intermediate channel
    /// widths `mid` (may be empty for a single 3×3 conv layer).
    pub fn new(
        side: usize,
        c_state: usize,
        mid: &[usize],
        time: TimeMode,
        rng: &mut Rng,
    ) -> Self {
        assert!(side > 0 && c_state > 0, "conv stem needs side, channels > 0");
        assert!(
            time != TimeMode::Concat,
            "time-concat has no image analogue; use TimeMode::Affine"
        );
        assert!(
            mid.iter().all(|&c| c > 0),
            "mid channel widths must be positive: {mid:?}"
        );
        let mut channels = Vec::with_capacity(mid.len() + 2);
        channels.push(c_state);
        channels.extend_from_slice(mid);
        channels.push(c_state);
        let layers = channels.len() - 1;
        let mut k_off = Vec::with_capacity(layers);
        let mut b_off = Vec::with_capacity(layers);
        let mut off = 0usize;
        for l in 0..layers {
            k_off.push(off);
            off += channels[l] * 9 * channels[l + 1];
            b_off.push(off);
            off += channels[l + 1];
        }
        let tw_off = off;
        if time == TimeMode::Affine {
            off += channels[1];
        }
        let mut theta = vec![0.0f32; off];
        for l in 0..layers {
            let fan_in = channels[l] * 9;
            let std = 0.5 / (fan_in as f64).sqrt();
            rng.fill_normal(
                &mut theta[k_off[l]..k_off[l] + fan_in * channels[l + 1]],
                std,
            );
        }
        if time == TimeMode::Affine {
            rng.fill_normal(&mut theta[tw_off..], 0.1);
        }
        let mut m = ConvStemDynamics {
            side,
            channels,
            time,
            theta,
            k_off,
            b_off,
            tw_off,
            kt: Vec::new(),
            counters: EvalCounters::default(),
            pool: ScratchPool::new(),
        };
        m.rebuild_kt();
        m
    }

    pub fn side(&self) -> usize {
        self.side
    }

    pub fn channel_dims(&self) -> &[usize] {
        &self.channels
    }

    fn hw(&self) -> usize {
        self.side * self.side
    }

    fn rebuild_kt(&mut self) {
        let layers = self.channels.len() - 1;
        while self.kt.len() < layers {
            self.kt.push(Vec::new());
        }
        for l in 0..layers {
            let (ind, outd) = (self.channels[l] * 9, self.channels[l + 1]);
            let k = &self.theta[self.k_off[l]..self.k_off[l] + ind * outd];
            let kt = &mut self.kt[l];
            ensure(kt, outd * ind);
            for i in 0..ind {
                for o in 0..outd {
                    kt[o * ind + i] = k[i * outd + o];
                }
            }
        }
    }

    /// Lower `[B, H, W, C_in]` into the `[B·H·W, C_in·9]` patch matrix
    /// (zero padding outside the image).
    fn im2col(&self, x: &[f32], batch: usize, cin: usize, cols: &mut [f32]) {
        let side = self.side as isize;
        let hw = self.hw();
        for b in 0..batch {
            let xrow = &x[b * hw * cin..(b + 1) * hw * cin];
            for y in 0..self.side {
                for xx in 0..self.side {
                    let r = (b * hw + y * self.side + xx) * cin * 9;
                    for ky in 0..3usize {
                        let sy = y as isize + ky as isize - 1;
                        for kx in 0..3usize {
                            let sx = xx as isize + kx as isize - 1;
                            let tap = r + (ky * 3 + kx) * cin;
                            let dst = &mut cols[tap..tap + cin];
                            if sy < 0 || sy >= side || sx < 0 || sx >= side {
                                dst.fill(0.0);
                            } else {
                                let s0 = ((sy as usize) * self.side + sx as usize) * cin;
                                dst.copy_from_slice(&xrow[s0..s0 + cin]);
                            }
                        }
                    }
                }
            }
        }
    }

    /// Scatter-add the patch-matrix cotangent back onto the image grid
    /// (the exact adjoint of [`ConvStemDynamics::im2col`]).  `dx` must be
    /// zeroed by the caller.
    fn col2im_add(&self, dcols: &[f32], batch: usize, cin: usize, dx: &mut [f32]) {
        let side = self.side as isize;
        let hw = self.hw();
        for b in 0..batch {
            let dxrow = &mut dx[b * hw * cin..(b + 1) * hw * cin];
            for y in 0..self.side {
                for xx in 0..self.side {
                    let r = (b * hw + y * self.side + xx) * cin * 9;
                    for ky in 0..3usize {
                        let sy = y as isize + ky as isize - 1;
                        for kx in 0..3usize {
                            let sx = xx as isize + kx as isize - 1;
                            if sy < 0 || sy >= side || sx < 0 || sx >= side {
                                continue;
                            }
                            let tap = r + (ky * 3 + kx) * cin;
                            let s0 = ((sy as usize) * self.side + sx as usize) * cin;
                            for c in 0..cin {
                                dxrow[s0 + c] += dcols[tap + c];
                            }
                        }
                    }
                }
            }
        }
    }

    /// One conv layer on a staged patch matrix: matmul, per-pixel bias,
    /// optional layer-0 time-affine, tanh unless `last`.
    fn layer_from_cols(
        &self,
        l: usize,
        ts: &[f64],
        batch: usize,
        cols: &[f32],
        dst: &mut [f32],
    ) {
        let hw = self.hw();
        let (ind, outd) = (self.channels[l] * 9, self.channels[l + 1]);
        let k = &self.theta[self.k_off[l]..self.k_off[l] + ind * outd];
        let bias = &self.theta[self.b_off[l]..self.b_off[l] + outd];
        matmul_into(cols, k, batch * hw, ind, outd, dst);
        for r in 0..batch * hw {
            axpy(1.0, bias, &mut dst[r * outd..(r + 1) * outd]);
        }
        if l == 0 && self.time == TimeMode::Affine {
            let tw = &self.theta[self.tw_off..self.tw_off + outd];
            for r in 0..batch * hw {
                axpy(ts[r / hw] as f32, tw, &mut dst[r * outd..(r + 1) * outd]);
            }
        }
        if l < self.channels.len() - 2 {
            for v in dst.iter_mut() {
                *v = v.tanh();
            }
        }
    }
}

impl NativeLayered for ConvStemDynamics {
    fn n_state(&self) -> usize {
        self.hw() * self.channels[0]
    }

    fn n_params(&self) -> usize {
        self.theta.len()
    }

    fn theta_ref(&self) -> &[f32] {
        &self.theta
    }

    fn set_theta(&mut self, theta: &[f32]) {
        self.theta.copy_from_slice(theta);
        self.rebuild_kt();
    }

    fn counters_ref(&self) -> &EvalCounters {
        &self.counters
    }

    fn pool_ref(&self) -> &ScratchPool {
        &self.pool
    }

    fn nf_depth(&self) -> usize {
        self.channels.len() - 1
    }

    fn forward_core(
        &self,
        ts: &[f64],
        x: &[f32],
        batch: usize,
        s: &mut LayerScratch,
        out: &mut [f32],
    ) {
        let hw = self.hw();
        let layers = self.channels.len() - 1;
        let act_sizes: Vec<usize> = (0..layers).map(|l| hw * self.channels[l]).collect();
        let col_sizes: Vec<usize> = (0..layers).map(|l| hw * self.channels[l] * 9).collect();
        let LayerScratch { acts, cols, .. } = s;
        ensure_layers(acts, &act_sizes, batch);
        ensure_layers(cols, &col_sizes, batch);
        acts[0].copy_from_slice(x);
        for l in 0..layers {
            let last = l == layers - 1;
            self.im2col(&acts[l], batch, self.channels[l], &mut cols[l]);
            let (_, tail) = acts.split_at_mut(l + 1);
            let dst: &mut [f32] = if last { &mut out[..] } else { &mut tail[0][..] };
            self.layer_from_cols(l, ts, batch, &cols[l], dst);
        }
    }

    fn vjp_core(
        &self,
        ts: &[f64],
        x: &[f32],
        a: &[f32],
        batch: usize,
        s: &mut LayerScratch,
        ax: &mut [f32],
        ath_acc: &mut [f32],
    ) {
        let hw = self.hw();
        let layers = self.channels.len() - 1;
        let act_sizes: Vec<usize> = (0..layers).map(|l| hw * self.channels[l]).collect();
        let col_sizes: Vec<usize> = (0..layers).map(|l| hw * self.channels[l] * 9).collect();
        let LayerScratch {
            acts,
            cols,
            ca,
            cb,
            xt,
            dw,
            dcols,
            ..
        } = s;
        // staging pass: every layer's input activation *and* patch matrix
        // (the last layer's own matmul output is not needed)
        ensure_layers(acts, &act_sizes, batch);
        ensure_layers(cols, &col_sizes, batch);
        acts[0].copy_from_slice(x);
        for l in 0..layers {
            self.im2col(&acts[l], batch, self.channels[l], &mut cols[l]);
            if l < layers - 1 {
                let (_, tail) = acts.split_at_mut(l + 1);
                self.layer_from_cols(l, ts, batch, &cols[l], &mut tail[0][..]);
            }
        }
        // backward walk
        let mut cur: &mut Vec<f32> = ca;
        let mut nxt: &mut Vec<f32> = cb;
        for l in (0..layers).rev() {
            let cin = self.channels[l];
            let (ind, outd) = (cin * 9, self.channels[l + 1]);
            let d_pre: &[f32] = if l == layers - 1 { a } else { &cur[..] };
            // d_b += per-pixel column sum
            {
                let b_acc = &mut ath_acc[self.b_off[l]..self.b_off[l] + outd];
                for r in 0..batch * hw {
                    axpy(1.0, &d_pre[r * outd..(r + 1) * outd], b_acc);
                }
            }
            if l == 0 && self.time == TimeMode::Affine {
                let tw_acc = &mut ath_acc[self.tw_off..self.tw_off + outd];
                for r in 0..batch * hw {
                    axpy(
                        ts[r / hw] as f32,
                        &d_pre[r * outd..(r + 1) * outd],
                        tw_acc,
                    );
                }
            }
            // d_K += colsᵀ · d_pre
            {
                let src = &cols[l][..batch * hw * ind];
                ensure(xt, ind * batch * hw);
                for r in 0..batch * hw {
                    for i in 0..ind {
                        xt[i * batch * hw + r] = src[r * ind + i];
                    }
                }
                ensure(dw, ind * outd);
                matmul_into(xt, d_pre, ind, batch * hw, outd, dw);
                axpy(
                    1.0,
                    &dw[..ind * outd],
                    &mut ath_acc[self.k_off[l]..self.k_off[l] + ind * outd],
                );
            }
            // d_x = col2im(d_pre · Kᵀ)
            ensure(dcols, batch * hw * ind);
            matmul_into(d_pre, &self.kt[l], batch * hw, outd, ind, dcols);
            ensure(nxt, batch * hw * cin);
            nxt.fill(0.0);
            self.col2im_add(dcols, batch, cin, nxt);
            if l > 0 {
                for (dv, &act) in nxt.iter_mut().zip(&acts[l]) {
                    *dv *= 1.0 - act * act;
                }
                std::mem::swap(&mut cur, &mut nxt);
            } else {
                ax.copy_from_slice(&nxt[..batch * hw * cin]);
            }
        }
    }
}

impl_dynamics_via_native_layered!(ConvStemDynamics);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::batch::BatchSpec;
    use crate::solvers::dynamics::Dynamics;

    /// im2col-lowered conv vjp matches central finite differences on z
    /// and θ (covers K, b, and the time-affine vector).
    #[test]
    fn conv_vjp_matches_finite_differences() {
        let mut rng = Rng::new(51);
        let mut dyn_ = ConvStemDynamics::new(4, 2, &[3], TimeMode::Affine, &mut rng);
        let n = Dynamics::dim(&dyn_);
        assert_eq!(n, 4 * 4 * 2);
        let mut z = vec![0.0f32; n];
        rng.fill_uniform_sym(&mut z, 0.6);
        let mut a = vec![0.0f32; n];
        rng.fill_uniform_sym(&mut a, 1.0);
        let t = 0.42;
        let (az, ath) = dyn_.f_vjp(t, &z, &a);
        let eps = 1e-3;
        for j in (0..n).step_by(3) {
            let mut zp = z.clone();
            zp[j] += eps as f32;
            let mut zm = z.clone();
            zm[j] -= eps as f32;
            let fp = dyn_.f(t, &zp);
            let fm = dyn_.f(t, &zm);
            let fd: f64 = fp
                .iter()
                .zip(&fm)
                .zip(&a)
                .map(|((&p, &m), &ai)| ((p - m) as f64 / (2.0 * eps)) * ai as f64)
                .sum();
            assert!(
                (fd - az[j] as f64).abs() < 5e-3,
                "a_z[{j}]: fd {fd} vs {}",
                az[j]
            );
        }
        let theta0 = dyn_.params().to_vec();
        let p = theta0.len();
        for &k in &[0usize, p / 4, p / 2, 3 * p / 4, p - 1] {
            let mut tp = theta0.clone();
            tp[k] += eps as f32;
            dyn_.set_params(&tp);
            let fp = dyn_.f(t, &z);
            let mut tm = theta0.clone();
            tm[k] -= eps as f32;
            dyn_.set_params(&tm);
            let fm = dyn_.f(t, &z);
            dyn_.set_params(&theta0);
            let fd: f64 = fp
                .iter()
                .zip(&fm)
                .zip(&a)
                .map(|((&p_, &m), &ai)| ((p_ - m) as f64 / (2.0 * eps)) * ai as f64)
                .sum();
            assert!(
                (fd - ath[k] as f64).abs() < 5e-3,
                "a_θ[{k}]: fd {fd} vs {}",
                ath[k]
            );
        }
    }

    /// Batched conv forward and `a_z` agree with the solo rows bitwise —
    /// im2col is per-sample and matmul rows are independent.
    #[test]
    fn conv_batch_matches_solo_rows() {
        let mut rng = Rng::new(53);
        let dyn_ = ConvStemDynamics::new(3, 2, &[4], TimeMode::None, &mut rng);
        let n = Dynamics::dim(&dyn_);
        let spec = BatchSpec::new(3, n);
        let mut z = vec![0.0f32; spec.flat_len()];
        rng.fill_uniform_sym(&mut z, 0.5);
        let ts = [0.0, 0.5, 1.0];
        let fb = dyn_.f_batch(&ts, &z, &spec);
        for (b, &t) in ts.iter().enumerate() {
            assert_eq!(
                spec.row(&fb, b),
                dyn_.f(t, spec.row(&z, b)).as_slice(),
                "f row {b}"
            );
        }
        let mut a = vec![0.0f32; spec.flat_len()];
        rng.fill_uniform_sym(&mut a, 1.0);
        let (azb, _) = dyn_.f_vjp_batch(&ts, &z, &a, &spec);
        for (b, &t) in ts.iter().enumerate() {
            let (az, _) = dyn_.f_vjp(t, spec.row(&z, b), spec.row(&a, b));
            assert_eq!(spec.row(&azb, b), az.as_slice(), "a_z row {b}");
        }
    }

    #[test]
    #[should_panic]
    fn conv_rejects_time_concat() {
        let mut rng = Rng::new(1);
        ConvStemDynamics::new(4, 2, &[3], TimeMode::Concat, &mut rng);
    }
}
