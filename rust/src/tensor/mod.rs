//! Host-side tensors and the vector math used by solver stage arithmetic.
//!
//! Device compute (the model's `f`, ψ, vjp graphs) runs through PJRT; what
//! remains on the host is O(N_z) stage combination (`axpy`-style), error
//! norms for the adaptive controller, and optimizer updates.  All f32 with
//! f64 accumulation for reductions.

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Tensor {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "data/shape mismatch: {} vs {:?}",
            data.len(),
            shape
        );
        Tensor { data, shape }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            data: vec![0.0; shape.iter().product()],
            shape: shape.to_vec(),
        }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor {
            data: vec![v],
            shape: vec![],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.shape.len(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }
}

// ---- flat-slice vector ops -------------------------------------------------

/// y += a * x
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// out = x + a * y   (allocating)
pub fn add_scaled(x: &[f32], a: f32, y: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(xi, yi)| xi + a * yi).collect()
}

/// Per-row `y[b] += coeffs[b] · x[b]` over row-major `[B, n_z]` buffers —
/// the batched solvers' stage arithmetic, where each sample carries its
/// own step size.  Row arithmetic is identical to [`axpy`] on the row.
pub fn axpy_rows(coeffs: &[f32], x: &[f32], y: &mut [f32], n_z: usize) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(coeffs.len() * n_z, y.len());
    for (b, &c) in coeffs.iter().enumerate() {
        axpy(c, &x[b * n_z..(b + 1) * n_z], &mut y[b * n_z..(b + 1) * n_z]);
    }
}

/// Allocating per-row `out[b] = x[b] + coeffs[b] · y[b]` (the batched
/// counterpart of [`add_scaled`]).
pub fn add_scaled_rows(x: &[f32], coeffs: &[f32], y: &[f32], n_z: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), y.len());
    let mut out = x.to_vec();
    axpy_rows(coeffs, y, &mut out, n_z);
    out
}

/// out = sum_i c_i * xs_i  (linear combination, allocating)
pub fn lincomb(terms: &[(f32, &[f32])]) -> Vec<f32> {
    let n = terms.first().map(|(_, x)| x.len()).unwrap_or(0);
    let mut out = vec![0.0f32; n];
    for &(c, x) in terms {
        axpy(c, x, &mut out);
    }
    out
}

pub fn scale_in_place(a: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(&a, &b)| a as f64 * b as f64)
        .sum()
}

pub fn nrm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

pub fn max_abs(x: &[f32]) -> f64 {
    x.iter().map(|v| v.abs() as f64).fold(0.0, f64::max)
}

/// Hairer-style scaled RMS error norm:
/// `sqrt( mean_i ( e_i / (atol + rtol * max(|z0_i|, |z1_i|)) )^2 )`.
/// Accept a step when this is <= 1.
pub fn error_norm(err: &[f32], z0: &[f32], z1: &[f32], rtol: f64, atol: f64) -> f64 {
    debug_assert_eq!(err.len(), z0.len());
    debug_assert_eq!(err.len(), z1.len());
    if err.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for i in 0..err.len() {
        let sc = atol + rtol * (z0[i].abs().max(z1[i].abs()) as f64);
        let r = err[i] as f64 / sc;
        acc += r * r;
    }
    (acc / err.len() as f64).sqrt()
}

/// Seminorm variant (Kidger et al. 2020a, "Hey, that's not an ODE"): the
/// error components belonging to the adjoint-parameter block are excluded
/// from the norm (`mask[i] = false`), accelerating adjoint backward passes.
pub fn error_seminorm(
    err: &[f32],
    z0: &[f32],
    z1: &[f32],
    mask: &[bool],
    rtol: f64,
    atol: f64,
) -> f64 {
    let mut acc = 0.0f64;
    let mut n = 0usize;
    for i in 0..err.len() {
        if !mask[i] {
            continue;
        }
        let sc = atol + rtol * (z0[i].abs().max(z1[i].abs()) as f64);
        let r = err[i] as f64 / sc;
        acc += r * r;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        (acc / n as f64).sqrt()
    }
}

/// Naive matmul (m,k)x(k,n) for native-dynamics tests and tiny models; the
/// real model matmuls run inside XLA.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// argmax of each row of a (rows, cols) matrix — classification decisions.
pub fn argmax_rows(logits: &[f32], rows: usize, cols: usize) -> Vec<usize> {
    assert_eq!(logits.len(), rows * cols);
    (0..rows)
        .map(|r| {
            let row = &logits[r * cols..(r + 1) * cols];
            // NaN-safe: a diverged solver (e.g. the re-discretized ResNet
            // probe of Table 2) may emit NaN logits — rank them lowest so
            // the prediction is simply wrong rather than a panic.
            row.iter()
                .enumerate()
                .max_by(|a, b| {
                    a.1.partial_cmp(b.1)
                        .unwrap_or_else(|| a.1.is_nan().cmp(&b.1.is_nan()).reverse())
                })
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_survives_nan_rows() {
        let logits = [f32::NAN, 1.0, 0.5, /* row 2 */ 2.0, f32::NAN, 0.0];
        let picks = argmax_rows(&logits, 2, 3);
        assert_eq!(picks, vec![1, 0]);
        // an all-NaN row must not panic (pick is arbitrary)
        let all_nan = [f32::NAN, f32::NAN];
        assert_eq!(argmax_rows(&all_nan, 1, 2).len(), 1);
    }

    #[test]
    fn axpy_and_lincomb() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 14.0, 16.0]);
        let out = lincomb(&[(1.0, &x[..]), (0.5, &y[..])]);
        assert_eq!(out, vec![7.0, 9.0, 11.0]);
    }

    #[test]
    fn row_scaled_ops_match_per_row_axpy() {
        let x = [1.0f32, 2.0, 3.0, 4.0]; // 2 rows of 2
        let y = [10.0f32, 20.0, 30.0, 40.0];
        let coeffs = [2.0f32, -1.0];
        let out = add_scaled_rows(&x, &coeffs, &y, 2);
        assert_eq!(out, vec![21.0, 42.0, -27.0, -36.0]);
        let mut acc = x;
        axpy_rows(&coeffs, &y, &mut acc, 2);
        assert_eq!(acc.to_vec(), out);
        // row b must equal add_scaled on that row
        assert_eq!(&out[2..], add_scaled(&x[2..], coeffs[1], &y[2..]).as_slice());
    }

    #[test]
    fn norms() {
        let x = [3.0f32, 4.0];
        assert!((nrm2(&x) - 5.0).abs() < 1e-12);
        assert_eq!(max_abs(&[-7.0, 2.0]), 7.0);
        assert!((dot(&x, &x) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn error_norm_accepts_small_errors() {
        let z = [1.0f32, -2.0, 0.5];
        let err_small = [1e-9f32, 1e-9, 1e-9];
        let en = error_norm(&err_small, &z, &z, 1e-3, 1e-6);
        assert!(en < 1.0, "{en}");
        let err_big = [1.0f32, 1.0, 1.0];
        assert!(error_norm(&err_big, &z, &z, 1e-3, 1e-6) > 1.0);
    }

    #[test]
    fn seminorm_ignores_masked_components() {
        let z = [1.0f32, 1.0];
        let err = [0.0f32, 100.0];
        let full = error_norm(&err, &z, &z, 1e-3, 1e-6);
        let semi = error_seminorm(&err, &z, &z, &[true, false], 1e-3, 1e-6);
        assert!(full > 1.0);
        assert_eq!(semi, 0.0);
    }

    #[test]
    fn matmul_identity_and_known() {
        let a = [1.0f32, 2.0, 3.0, 4.0]; // 2x2
        let eye = [1.0f32, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &eye, 2, 2, 2), a.to_vec());
        let b = [1.0f32, 1.0, 1.0, 1.0];
        assert_eq!(matmul(&a, &b, 2, 2, 2), vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn argmax_rows_works() {
        let logits = [0.1f32, 0.9, 0.0, 5.0, -1.0, 2.0];
        assert_eq!(argmax_rows(&logits, 2, 3), vec![1, 0]);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_checked() {
        Tensor::new(vec![1.0, 2.0], vec![3]);
    }
}
