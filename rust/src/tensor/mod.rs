//! Host-side tensors and the vector math used by solver stage arithmetic.
//!
//! Device compute (the model's `f`, ψ, vjp graphs) runs through PJRT; what
//! remains on the host is O(N_z) stage combination (`axpy`-style), error
//! norms for the adaptive controller, and optimizer updates.  All f32 with
//! f64 accumulation for reductions.
//!
//! # Kernel dispatch contract
//!
//! The hot kernels ([`axpy`], [`add_scaled_into`], [`axpy_rows`],
//! [`add_scaled_rows_into`], [`lincomb_into`], [`matmul_into`]) are
//! alignment-aware, chunked-with-remainder implementations: a scalar head
//! peels until the destination pointer is `LANES`-aligned, the body runs in
//! fixed `LANES`-wide chunks, and a scalar tail handles the remainder.  By
//! default the chunk body is plain indexed arithmetic over `[f32; LANES]`
//! arrays (which LLVM autovectorizes on stable); with the `simd` cargo
//! feature it uses `std::simd` explicitly (nightly-only, see ADR-004).
//!
//! Every dispatch kernel is **bitwise identical** to its reference in
//! [`scalar`] for all inputs: the chunked kernels perform exactly the same
//! per-element operations (one `a * x[i]` product and one add each — Rust
//! never contracts these into an FMA), and regrouping elementwise work into
//! lanes cannot change any element's value.  [`matmul_into`] keeps a fixed
//! ascending-`p` accumulation order per output element.  This identity is
//! pinned by `tests/prop_kernels.rs` under both feature settings; the
//! [`scalar`] module is the frozen oracle and must stay loop-simple.

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Tensor {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "data/shape mismatch: {} vs {:?}",
            data.len(),
            shape
        );
        Tensor { data, shape }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            data: vec![0.0; shape.iter().product()],
            shape: shape.to_vec(),
        }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor {
            data: vec![v],
            shape: vec![],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.shape.len(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }
}

// ---- kernel dispatch machinery --------------------------------------------

/// Lane width of the chunked kernels: 8 f32 = one 256-bit vector register
/// (AVX2 / 2×NEON), the widest width `std::simd` lowers well everywhere.
pub const LANES: usize = 8;

/// Whether this build dispatches the `std::simd` chunk bodies (`simd`
/// cargo feature) rather than the autovectorized array bodies.  Recorded in
/// `BENCH_hotpath.json` so perf rows are attributable to a dispatch path.
pub fn simd_enabled() -> bool {
    cfg!(feature = "simd")
}

/// Number of scalar elements to peel so `p` reaches a `LANES * 4`-byte
/// boundary (capped at `len`).  f32 slices are always 4-byte aligned, so
/// the misalignment is a whole number of elements.
#[inline]
fn align_head(p: *const f32, len: usize) -> usize {
    let bytes = LANES * 4;
    let mis = (p as usize) % bytes;
    if mis == 0 {
        0
    } else {
        ((bytes - mis) / 4).min(len)
    }
}

/// One-`LANES`-chunk bodies.  Exactly one definition is compiled; both
/// perform the identical per-element arithmetic (load, one multiply, one
/// add, store — no FMA contraction, no reassociation), which is what makes
/// the dispatch kernels bitwise-equal to [`scalar`].
#[cfg(feature = "simd")]
mod lanes {
    use super::LANES;
    use std::simd::Simd;

    type V = Simd<f32, LANES>;

    /// `y += a * x` on one chunk.
    #[inline(always)]
    pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        let r = V::from_slice(y) + V::splat(a) * V::from_slice(x);
        r.copy_to_slice(y);
    }

    /// `out = x + a * y` on one chunk.
    #[inline(always)]
    pub fn add_scaled(x: &[f32], a: f32, y: &[f32], out: &mut [f32]) {
        let r = V::from_slice(x) + V::splat(a) * V::from_slice(y);
        r.copy_to_slice(out);
    }
}

#[cfg(not(feature = "simd"))]
mod lanes {
    use super::LANES;

    /// `y += a * x` on one chunk (array-typed so LLVM sees the constant
    /// trip count and autovectorizes without bounds checks).
    #[inline(always)]
    pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        let x: &[f32; LANES] = x.try_into().expect("chunk");
        let y: &mut [f32; LANES] = y.try_into().expect("chunk");
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += a * xi;
        }
    }

    /// `out = x + a * y` on one chunk.
    #[inline(always)]
    pub fn add_scaled(x: &[f32], a: f32, y: &[f32], out: &mut [f32]) {
        let x: &[f32; LANES] = x.try_into().expect("chunk");
        let y: &[f32; LANES] = y.try_into().expect("chunk");
        let out: &mut [f32; LANES] = out.try_into().expect("chunk");
        for ((o, xi), yi) in out.iter_mut().zip(x).zip(y) {
            *o = xi + a * yi;
        }
    }
}

/// Reference (oracle) kernels: the loop-simple implementations the chunked
/// dispatch kernels must match **bitwise** (`tests/prop_kernels.rs`).
///
/// These are the pre-vectorization hot-path kernels, frozen.  Do not
/// "optimize" them — their value is being obviously correct; the public
/// kernels carry the performance.
pub mod scalar {
    /// y += a * x
    pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += a * xi;
        }
    }

    /// `out[i] = x[i] + a * y[i]`.
    pub fn add_scaled_into(x: &[f32], a: f32, y: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        debug_assert_eq!(x.len(), out.len());
        for ((o, xi), yi) in out.iter_mut().zip(x).zip(y) {
            *o = xi + a * yi;
        }
    }

    /// Per-row `y[b] += coeffs[b] · x[b]` over row-major `[B, n_z]`.
    pub fn axpy_rows(coeffs: &[f32], x: &[f32], y: &mut [f32], n_z: usize) {
        debug_assert_eq!(x.len(), y.len());
        debug_assert_eq!(coeffs.len() * n_z, y.len());
        for (b, &c) in coeffs.iter().enumerate() {
            axpy(c, &x[b * n_z..(b + 1) * n_z], &mut y[b * n_z..(b + 1) * n_z]);
        }
    }

    /// Per-row `out[b] = x[b] + coeffs[b] · y[b]` (copy then [`axpy_rows`]).
    pub fn add_scaled_rows_into(
        x: &[f32],
        coeffs: &[f32],
        y: &[f32],
        n_z: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(x.len(), y.len());
        debug_assert_eq!(x.len(), out.len());
        out.copy_from_slice(x);
        axpy_rows(coeffs, y, out, n_z);
    }

    /// `out = Σ_i c_i · xs_i`, term-by-term in slice order (zero-fill then
    /// [`axpy`] each term, including zero-coefficient terms).
    pub fn lincomb_into(terms: &[(f32, &[f32])], out: &mut [f32]) {
        out.fill(0.0);
        for &(c, x) in terms {
            axpy(c, x, out);
        }
    }

    /// Column-blocked `out = a · b` with a scalar inner strip loop; same
    /// blocking and zero-skip as the public [`super::matmul_into`], so both
    /// walk every output element with the identical ascending-`p`
    /// accumulation order.
    pub fn matmul_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(out.len(), m * n);
        out.fill(0.0);
        let mut j0 = 0usize;
        while j0 < n {
            let j1 = (j0 + super::MATMUL_JBLOCK).min(n);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let orow = &mut out[i * n + j0..i * n + j1];
                for (p, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[p * n + j0..p * n + j1];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
            j0 = j1;
        }
    }
}

// ---- flat-slice vector ops -------------------------------------------------

/// y += a * x — chunked dispatch kernel, bitwise equal to [`scalar::axpy`].
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let head = align_head(y.as_ptr(), y.len());
    let (yh, yt) = y.split_at_mut(head);
    let (xh, xt) = x.split_at(head);
    scalar::axpy(a, xh, yh);
    let mut yc = yt.chunks_exact_mut(LANES);
    let mut xc = xt.chunks_exact(LANES);
    for (yk, xk) in (&mut yc).zip(&mut xc) {
        lanes::axpy(a, xk, yk);
    }
    scalar::axpy(a, xc.remainder(), yc.into_remainder());
}

/// out = x + a * y   (allocating wrapper over [`add_scaled_into`])
pub fn add_scaled(x: &[f32], a: f32, y: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    add_scaled_into(x, a, y, &mut out);
    out
}

/// `out[i] = x[i] + a * y[i]` into a caller-provided buffer — the
/// workspace-path kernel behind [`add_scaled`], chunked dispatch, bitwise
/// equal to [`scalar::add_scaled_into`].  `out` may alias neither input
/// slice (enforced by the borrow checker).
pub fn add_scaled_into(x: &[f32], a: f32, y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    let head = align_head(out.as_ptr(), out.len());
    let (oh, ot) = out.split_at_mut(head);
    let (xh, xt) = x.split_at(head);
    let (yh, yt) = y.split_at(head);
    scalar::add_scaled_into(xh, a, yh, oh);
    let mut oc = ot.chunks_exact_mut(LANES);
    let mut xc = xt.chunks_exact(LANES);
    let mut yc = yt.chunks_exact(LANES);
    for ((ok, xk), yk) in (&mut oc).zip(&mut xc).zip(&mut yc) {
        lanes::add_scaled(xk, a, yk, ok);
    }
    scalar::add_scaled_into(xc.remainder(), a, yc.remainder(), oc.into_remainder());
}

/// Per-row `y[b] += coeffs[b] · x[b]` over row-major `[B, n_z]` buffers —
/// the batched solvers' stage arithmetic, where each sample carries its
/// own step size.  Row arithmetic is identical to [`axpy`] on the row,
/// hence bitwise equal to [`scalar::axpy_rows`].
pub fn axpy_rows(coeffs: &[f32], x: &[f32], y: &mut [f32], n_z: usize) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(coeffs.len() * n_z, y.len());
    for (b, &c) in coeffs.iter().enumerate() {
        axpy(c, &x[b * n_z..(b + 1) * n_z], &mut y[b * n_z..(b + 1) * n_z]);
    }
}

/// Allocating per-row `out[b] = x[b] + coeffs[b] · y[b]` (the batched
/// counterpart of [`add_scaled`]; wrapper over [`add_scaled_rows_into`]).
pub fn add_scaled_rows(x: &[f32], coeffs: &[f32], y: &[f32], n_z: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    add_scaled_rows_into(x, coeffs, y, n_z, &mut out);
    out
}

/// Per-row `out[b] = x[b] + coeffs[b] · y[b]` into a caller-provided
/// buffer — bit-identical to [`add_scaled_rows`] (copy then [`axpy_rows`])
/// and to [`scalar::add_scaled_rows_into`].
pub fn add_scaled_rows_into(x: &[f32], coeffs: &[f32], y: &[f32], n_z: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    out.copy_from_slice(x);
    axpy_rows(coeffs, y, out, n_z);
}

/// out = sum_i c_i * xs_i  (linear combination; wrapper over
/// [`lincomb_into`])
pub fn lincomb(terms: &[(f32, &[f32])]) -> Vec<f32> {
    let n = terms.first().map(|(_, x)| x.len()).unwrap_or(0);
    let mut out = vec![0.0f32; n];
    lincomb_into(terms, &mut out);
    out
}

/// `out = Σ_i c_i · xs_i` into a caller-provided buffer, accumulating
/// term-by-term in slice order exactly like [`lincomb`] (zero-fill then
/// [`axpy`] each term, including zero-coefficient terms) — bitwise equal
/// to [`scalar::lincomb_into`].
pub fn lincomb_into(terms: &[(f32, &[f32])], out: &mut [f32]) {
    out.fill(0.0);
    for &(c, x) in terms {
        axpy(c, x, out);
    }
}

pub fn scale_in_place(a: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(&a, &b)| a as f64 * b as f64)
        .sum()
}

pub fn nrm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

pub fn max_abs(x: &[f32]) -> f64 {
    x.iter().map(|v| v.abs() as f64).fold(0.0, f64::max)
}

/// Hairer-style scaled RMS error norm:
/// `sqrt( mean_i ( e_i / (atol + rtol * max(|z0_i|, |z1_i|)) )^2 )`.
/// Accept a step when this is <= 1.
pub fn error_norm(err: &[f32], z0: &[f32], z1: &[f32], rtol: f64, atol: f64) -> f64 {
    debug_assert_eq!(err.len(), z0.len());
    debug_assert_eq!(err.len(), z1.len());
    if err.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for i in 0..err.len() {
        let sc = atol + rtol * (z0[i].abs().max(z1[i].abs()) as f64);
        let r = err[i] as f64 / sc;
        acc += r * r;
    }
    (acc / err.len() as f64).sqrt()
}

/// Seminorm variant (Kidger et al. 2020a, "Hey, that's not an ODE"): the
/// error components belonging to the adjoint-parameter block are excluded
/// from the norm (`mask[i] = false`), accelerating adjoint backward passes.
pub fn error_seminorm(
    err: &[f32],
    z0: &[f32],
    z1: &[f32],
    mask: &[bool],
    rtol: f64,
    atol: f64,
) -> f64 {
    let mut acc = 0.0f64;
    let mut n = 0usize;
    for i in 0..err.len() {
        if !mask[i] {
            continue;
        }
        let sc = atol + rtol * (z0[i].abs().max(z1[i].abs()) as f64);
        let r = err[i] as f64 / sc;
        acc += r * r;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        (acc / n as f64).sqrt()
    }
}

/// Host matmul (m,k)x(k,n) for native-dynamics tests and tiny models; the
/// real model matmuls run inside XLA.  Allocating wrapper over
/// [`matmul_into`].
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_into(a, b, m, k, n, &mut out);
    out
}

/// Column-tile width of the blocked [`matmul_into`]: 64 f32 columns = one
/// 256-byte strip of `b` and `out`, small enough that a `b`-row strip plus
/// an `out`-row strip stay L1-resident across the `p` loop.
const MATMUL_JBLOCK: usize = 64;

/// `out = a · b` into a caller-provided `m·n` buffer, row-major and
/// column-blocked: for each output row the inner loops walk a `MATMUL_JBLOCK`
/// strip of `b`/`out` over all of `k`, so both strips stay cache-resident
/// instead of streaming the whole `b` per row.  The inner strip update is
/// [`axpy`]`(a[i,p], b_strip, out_strip)` — vectorized across `j`, which
/// leaves each output element's accumulation order over `p` ascending,
/// bit-identical to the straightforward i/p/j triple loop, to
/// [`scalar::matmul_into`], and to [`matmul`] (which wraps this).
pub fn matmul_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    let mut j0 = 0usize;
    while j0 < n {
        let j1 = (j0 + MATMUL_JBLOCK).min(n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n + j0..i * n + j1];
            for (p, &av) in arow.iter().enumerate() {
                // keep the zero-skip of the original kernel: sparse stage
                // coefficients (RK tableaus) hit it constantly
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n + j0..p * n + j1];
                axpy(av, brow, orow);
            }
        }
        j0 = j1;
    }
}

/// argmax of each row of a (rows, cols) matrix — classification decisions.
pub fn argmax_rows(logits: &[f32], rows: usize, cols: usize) -> Vec<usize> {
    assert_eq!(logits.len(), rows * cols);
    (0..rows)
        .map(|r| {
            let row = &logits[r * cols..(r + 1) * cols];
            // NaN-safe: a diverged solver (e.g. the re-discretized ResNet
            // probe of Table 2) may emit NaN logits — rank them lowest so
            // the prediction is simply wrong rather than a panic.
            row.iter()
                .enumerate()
                .max_by(|a, b| {
                    a.1.partial_cmp(b.1)
                        .unwrap_or_else(|| a.1.is_nan().cmp(&b.1.is_nan()).reverse())
                })
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_survives_nan_rows() {
        let logits = [f32::NAN, 1.0, 0.5, /* row 2 */ 2.0, f32::NAN, 0.0];
        let picks = argmax_rows(&logits, 2, 3);
        assert_eq!(picks, vec![1, 0]);
        // an all-NaN row must not panic (pick is arbitrary)
        let all_nan = [f32::NAN, f32::NAN];
        assert_eq!(argmax_rows(&all_nan, 1, 2).len(), 1);
    }

    #[test]
    fn axpy_and_lincomb() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 14.0, 16.0]);
        let out = lincomb(&[(1.0, &x[..]), (0.5, &y[..])]);
        assert_eq!(out, vec![7.0, 9.0, 11.0]);
    }

    #[test]
    fn row_scaled_ops_match_per_row_axpy() {
        let x = [1.0f32, 2.0, 3.0, 4.0]; // 2 rows of 2
        let y = [10.0f32, 20.0, 30.0, 40.0];
        let coeffs = [2.0f32, -1.0];
        let out = add_scaled_rows(&x, &coeffs, &y, 2);
        assert_eq!(out, vec![21.0, 42.0, -27.0, -36.0]);
        let mut acc = x;
        axpy_rows(&coeffs, &y, &mut acc, 2);
        assert_eq!(acc.to_vec(), out);
        // row b must equal add_scaled on that row
        assert_eq!(&out[2..], add_scaled(&x[2..], coeffs[1], &y[2..]).as_slice());
    }

    /// Dispatch kernels equal the scalar oracle bitwise on a width that
    /// exercises head + body + tail at once (the exhaustive sweep lives in
    /// `tests/prop_kernels.rs`; this is the in-crate smoke version).
    #[test]
    fn dispatch_matches_scalar_oracle_smoke() {
        let mut rng = crate::util::rng::Rng::new(42);
        let mut backing_x = vec![0.0f32; 64];
        let mut backing_y = vec![0.0f32; 64];
        rng.fill_normal(&mut backing_x, 1.0);
        rng.fill_normal(&mut backing_y, 1.0);
        for off in 0..4usize {
            let w = 27; // head + 3 chunks + tail for every offset
            let x = &backing_x[off..off + w];
            let y0 = &backing_y[off..off + w];
            let mut y_k = y0.to_vec();
            let mut y_s = y0.to_vec();
            axpy(0.37, x, &mut y_k);
            scalar::axpy(0.37, x, &mut y_s);
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&y_k), bits(&y_s), "axpy offset {off}");

            let mut o_k = vec![0.0f32; w];
            let mut o_s = vec![0.0f32; w];
            add_scaled_into(x, -1.25, y0, &mut o_k);
            scalar::add_scaled_into(x, -1.25, y0, &mut o_s);
            assert_eq!(bits(&o_k), bits(&o_s), "add_scaled offset {off}");
        }
    }

    #[test]
    fn norms() {
        let x = [3.0f32, 4.0];
        assert!((nrm2(&x) - 5.0).abs() < 1e-12);
        assert_eq!(max_abs(&[-7.0, 2.0]), 7.0);
        assert!((dot(&x, &x) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn error_norm_accepts_small_errors() {
        let z = [1.0f32, -2.0, 0.5];
        let err_small = [1e-9f32, 1e-9, 1e-9];
        let en = error_norm(&err_small, &z, &z, 1e-3, 1e-6);
        assert!(en < 1.0, "{en}");
        let err_big = [1.0f32, 1.0, 1.0];
        assert!(error_norm(&err_big, &z, &z, 1e-3, 1e-6) > 1.0);
    }

    #[test]
    fn seminorm_ignores_masked_components() {
        let z = [1.0f32, 1.0];
        let err = [0.0f32, 100.0];
        let full = error_norm(&err, &z, &z, 1e-3, 1e-6);
        let semi = error_seminorm(&err, &z, &z, &[true, false], 1e-3, 1e-6);
        assert!(full > 1.0);
        assert_eq!(semi, 0.0);
    }

    /// The blocked `matmul_into` must be bit-identical to the plain i/p/j
    /// triple loop for shapes below, at and across the column-block width
    /// (the accumulation order per output element is the same).
    #[test]
    fn matmul_into_matches_reference_across_blocks() {
        let mut rng = crate::util::rng::Rng::new(77);
        let shapes = [(1usize, 1usize, 1usize), (3, 4, 5), (2, 7, 64), (3, 5, 65), (2, 3, 130)];
        for &(m, k, n) in &shapes {
            let mut a = vec![0.0f32; m * k];
            let mut b = vec![0.0f32; k * n];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            // sprinkle zeros so the zero-skip path is exercised
            a[0] = 0.0;
            let mut reference = vec![0.0f32; m * n];
            for i in 0..m {
                for p in 0..k {
                    let av = a[i * k + p];
                    if av == 0.0 {
                        continue;
                    }
                    for j in 0..n {
                        reference[i * n + j] += av * b[p * n + j];
                    }
                }
            }
            let mut out = vec![1.0f32; m * n]; // pre-filled: `_into` must overwrite
            matmul_into(&a, &b, m, k, n, &mut out);
            assert_eq!(out, reference, "({m},{k},{n})");
            assert_eq!(matmul(&a, &b, m, k, n), reference, "wrapper ({m},{k},{n})");
            let mut oracle = vec![1.0f32; m * n];
            scalar::matmul_into(&a, &b, m, k, n, &mut oracle);
            assert_eq!(out, oracle, "scalar oracle ({m},{k},{n})");
        }
    }

    /// The `_into` kernels write exactly what their allocating wrappers
    /// return (the wrappers delegate, so this pins the delegation).
    #[test]
    fn into_kernels_match_allocating_wrappers() {
        let mut rng = crate::util::rng::Rng::new(5);
        let n_z = 3usize;
        let rows = 2usize;
        let mut x = vec![0.0f32; rows * n_z];
        let mut y = vec![0.0f32; rows * n_z];
        rng.fill_normal(&mut x, 1.0);
        rng.fill_normal(&mut y, 1.0);
        let coeffs = [0.7f32, -1.3];

        let mut out = vec![9.0f32; x.len()];
        add_scaled_into(&x, 0.25, &y, &mut out);
        assert_eq!(out, add_scaled(&x, 0.25, &y));

        let mut out = vec![9.0f32; x.len()];
        add_scaled_rows_into(&x, &coeffs, &y, n_z, &mut out);
        assert_eq!(out, add_scaled_rows(&x, &coeffs, &y, n_z));

        let terms: Vec<(f32, &[f32])> = vec![(1.5, x.as_slice()), (0.0, y.as_slice())];
        let mut out = vec![9.0f32; x.len()];
        lincomb_into(&terms, &mut out);
        assert_eq!(out, lincomb(&terms));
    }

    #[test]
    fn matmul_identity_and_known() {
        let a = [1.0f32, 2.0, 3.0, 4.0]; // 2x2
        let eye = [1.0f32, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &eye, 2, 2, 2), a.to_vec());
        let b = [1.0f32, 1.0, 1.0, 1.0];
        assert_eq!(matmul(&a, &b, 2, 2, 2), vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn argmax_rows_works() {
        let logits = [0.1f32, 0.9, 0.0, 5.0, -1.0, 2.0];
        assert_eq!(argmax_rows(&logits, 2, 3), vec![1, 0]);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_checked() {
        Tensor::new(vec![1.0, 2.0], vec![3]);
    }
}
