//! MALI — Memory-efficient ALF Integrator (paper Algorithm 4).
//!
//! Forward: adaptive/fixed ALF integration keeping only the end state
//! `(z_N, v_N)` and the accepted time grid `{t_i}` (the step-size search
//! process is discarded).  Backward: for each accepted step, reconstruct
//! `(z_{i-1}, v_{i-1}) = ψ⁻¹(z_i, v_i)` — exact because ALF is
//! algebraically invertible — then pull the adjoint pair `(a_z, a_v)` and
//! the parameter cotangent through ψ's vjp, and delete the local graph.
//!
//! Retained memory is one augmented state + the scalar time grid:
//! `N_z(N_f + 1)` in the paper's units, **constant in the number of solver
//! steps**, while the reverse-time trajectory equals the forward one to
//! float roundoff (unlike the adjoint method's re-solved IVP).
//!
//! Two details beyond the paper's pseudocode:
//! * `a_v(T) = 0`: the loss reads `z(T)` only, `v_N` is auxiliary.
//! * the initialisation `v₀ = f(z₀, t₀)` itself depends on `z₀` and θ, so
//!   after the step loop the leftover `a_v(t₀)` is pulled through that
//!   final `f` too — required for `dL/dz₀` (the FGSM experiments) to match
//!   finite differences exactly.

use super::{
    BatchGradResult, BatchLossHead, BatchObsGradResult, BatchObsLossHead, GradMethod, GradResult,
    GradStats, IvpSpec, LossHead, ObsGrid, ObsGradResult, ObsLossHead,
};
use crate::solvers::batch::{BatchSpec, BatchState};
use crate::solvers::dynamics::Dynamics;
use crate::solvers::integrate::{
    integrate_batch_obs_ws, integrate_batch_ws, integrate_obs_ws, integrate_ws,
    BatchGridRecorder, GridRecorder,
};
use crate::solvers::workspace::{BatchWorkspace, SolverWorkspace};
use crate::solvers::{Solver, State};
use crate::tensor::axpy;
use crate::util::mem::{MemTracker, TrackedBuf};
use anyhow::{ensure, Result};
use std::sync::Arc;

pub struct Mali;

impl GradMethod for Mali {
    fn name(&self) -> &'static str {
        "mali"
    }

    fn grad(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        z0: &[f32],
        loss: &dyn LossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<GradResult> {
        ensure!(
            solver.is_invertible(),
            "MALI requires an invertible solver (ALF); '{}' has no ψ⁻¹",
            solver.name()
        );
        let c = dynamics.counters();
        c.reset();
        let mut ws = SolverWorkspace::new();

        // ---- forward: keep end state + accepted grid only --------------
        let s0 = solver.init(dynamics, spec.t0, z0);
        let mut rec = GridRecorder::new(spec.t0);
        let fwd = integrate_ws(
            solver, dynamics, spec.t0, spec.t1, &s0, &spec.mode, &spec.norm, &mut rec, &mut ws,
        )?;
        let s_end = ws.take_output();
        // The retained footprint between passes: the augmented end state.
        // The accepted grid is O(N_t) *scalars* — the paper's Table-1
        // accounting is in N_z units and treats it as negligible, so it is
        // deliberately excluded from the tracked peak (it would otherwise
        // dominate the plot for tiny toy states at tight tolerances while
        // being irrelevant for any real model where N_z ≫ N_t).
        let kept_z = TrackedBuf::new(s_end.z.clone(), tracker.clone());
        let kept_v = TrackedBuf::new(
            s_end.v.clone().expect("ALF state carries v"),
            tracker.clone(),
        );

        let (loss_val, dl_dz) = loss.loss_grad(&kept_z.data);

        // ---- backward: reconstruct + local vjp, O(1) live state --------
        // The sweep ping-pongs between two reconstructed states and two
        // cotangent states, all borrowed from the workspace — after the
        // first iteration shapes are stable and each ψ⁻¹ + vjp micro-step
        // touches the allocator exactly zero times (the property
        // `tests/alloc_steady.rs` pins).
        let mut cur = s_end;
        let mut a = State {
            z: dl_dz,
            v: Some(vec![0.0f32; cur.z.len()]), // a_v(T) = 0
        };
        let mut prev = State {
            z: Vec::new(),
            v: None,
        };
        let mut a_prev = State {
            z: Vec::new(),
            v: None,
        };
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        let times = rec.times();
        let n = times.len() - 1;
        for i in (1..=n).rev() {
            let h = times[i] - times[i - 1];
            // reconstruct (z_{i-1}, v_{i-1}) via ψ⁻¹ and pull the adjoint
            // through the step — fused into one device call when the
            // dynamics exports the combined backward graph (§Perf)
            let ok = solver.invert_and_vjp_into(
                dynamics,
                times[i],
                h,
                &cur,
                &a,
                &mut prev,
                &mut a_prev,
                &mut grad_theta,
                &mut ws,
            );
            assert!(ok, "invertible solver");
            std::mem::swap(&mut cur, &mut prev);
            std::mem::swap(&mut a, &mut a_prev);
        }
        // final hop through v₀ = f(z₀, t₀)
        let mut grad_z0 = a.z.clone();
        if let Some(av0) = &a.v {
            if av0.iter().any(|&x| x != 0.0) {
                let (gz, gth) = dynamics.f_vjp(spec.t0, &cur.z, av0);
                axpy(1.0, &gz, &mut grad_z0);
                axpy(1.0, &gth, &mut grad_theta);
            }
        }

        let peak = tracker.peak_bytes();
        let stats = GradStats {
            bwd_steps: n,
            f_evals: c.f_evals.get(),
            vjp_evals: c.vjp_evals.get(),
            peak_mem_bytes: peak,
            graph_depth: dynamics.depth_nf() * n.max(1),
            fwd,
        };
        Ok(GradResult {
            loss: loss_val,
            z_final: kept_z.data.clone(),
            grad_theta,
            grad_z0,
            reconstructed_z0: Some(cur.z),
            stats,
        })
    }

    /// Batched MALI (Algo. 4 over `[B, N_z]` rows): the forward pass keeps
    /// only the flat end state plus one accepted grid *per sample*
    /// (per-sample adaptive control desynchronizes the grids); the
    /// backward pass sweeps ψ⁻¹ in lockstep over whichever rows still have
    /// steps left, so retained memory stays `B·N_z(N_f + 1)` — the Table-1
    /// law with `N_z → B·N_z` — while each row's reconstruction equals its
    /// solo run to float roundoff.
    #[allow(clippy::too_many_arguments)]
    fn grad_batch(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        z0: &[f32],
        bspec: &BatchSpec,
        loss: &dyn BatchLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<BatchGradResult> {
        ensure!(
            solver.is_invertible(),
            "MALI requires an invertible solver (ALF); '{}' has no ψ⁻¹",
            solver.name()
        );
        let c = dynamics.counters();
        let f0 = c.f_evals.get();
        let v0 = c.vjp_evals.get();
        let mut ws = BatchWorkspace::new();

        // ---- forward: end state + per-sample accepted grids ------------
        let s0 = solver.init_batch(dynamics, spec.t0, z0, bspec);
        let mut rec = BatchGridRecorder::new(spec.t0, bspec.batch);
        let fwd = integrate_batch_ws(
            solver, dynamics, spec.t0, spec.t1, &s0, &spec.mode, &spec.norm, &mut rec, &mut ws,
        )?;
        let s_end = ws.take_output();
        let kept_z = TrackedBuf::new(s_end.z.data.clone(), tracker.clone());
        let kept_v = TrackedBuf::new(
            s_end.v.as_ref().expect("ALF state carries v").data.clone(),
            tracker.clone(),
        );

        let (losses, dl_dz) = loss.loss_grad_batch(&kept_z.data, bspec);

        // ---- backward: lockstep ψ⁻¹ sweep over the still-remaining rows
        let mut cur = s_end;
        let mut a = BatchState::from_flat_zv(dl_dz, vec![0.0f32; bspec.flat_len()], *bspec);
        let mut prev = ws.take_batch(bspec.batch, bspec.n_z, true);
        let mut a_prev = ws.take_batch(bspec.batch, bspec.n_z, true);
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        let mut rem: Vec<usize> = rec.times.iter().map(|t| t.len() - 1).collect();
        let mut ts_out: Vec<f64> = Vec::new();
        let mut hs: Vec<f64> = Vec::new();
        loop {
            let active: Vec<usize> = (0..bspec.batch).filter(|&b| rem[b] > 0).collect();
            if active.is_empty() {
                break;
            }
            ts_out.clear();
            ts_out.extend(active.iter().map(|&b| rec.times[b][rem[b]]));
            hs.clear();
            hs.extend(
                active
                    .iter()
                    .map(|&b| rec.times[b][rem[b]] - rec.times[b][rem[b] - 1]),
            );
            // skip the gather/scatter copies while no row has dropped out
            // (always, under fixed stepping — the benchmarked hot path,
            // which then runs allocation-free out of the workspace)
            let full = active.len() == bspec.batch;
            if full {
                let ok = solver.invert_and_vjp_batch_into(
                    dynamics,
                    &ts_out,
                    &hs,
                    &cur,
                    &a,
                    &mut prev,
                    &mut a_prev,
                    &mut grad_theta,
                    &mut ws,
                );
                assert!(ok, "invertible solver");
                std::mem::swap(&mut cur, &mut prev);
                std::mem::swap(&mut a, &mut a_prev);
            } else {
                let cur_sub = cur.gather_rows(&active);
                let a_sub = a.gather_rows(&active);
                let (prev_sub, a_prev_sub, dth) = solver
                    .invert_and_vjp_batch(dynamics, &ts_out, &hs, &cur_sub, &a_sub)
                    .expect("invertible solver");
                axpy(1.0, &dth, &mut grad_theta);
                cur.scatter_rows(&prev_sub, &active);
                a.scatter_rows(&a_prev_sub, &active);
            }
            for &b in &active {
                rem[b] -= 1;
            }
        }

        // final hop through v₀ = f(z₀, t₀), only for rows whose a_v(t₀)
        // carries cotangent — shared with ACA/naive, here evaluated at the
        // ψ⁻¹-reconstructed initial states
        let mut grad_z0 = a.z.data.clone();
        super::aca::init_hop_batch(
            dynamics,
            spec.t0,
            &cur.z.data,
            bspec,
            &a,
            &mut grad_z0,
            &mut grad_theta,
        );

        let n_total: usize = rec.times.iter().map(|t| t.len() - 1).sum();
        let n_max: usize = rec.times.iter().map(|t| t.len() - 1).max().unwrap_or(0);
        let stats = GradStats {
            bwd_steps: n_total,
            f_evals: c.f_evals.get() - f0,
            vjp_evals: c.vjp_evals.get() - v0,
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * n_max.max(1),
            fwd: fwd.aggregate(),
        };
        Ok(BatchGradResult {
            batch: bspec.batch,
            n_z: bspec.n_z,
            loss: losses.iter().sum(),
            losses,
            z_final: kept_z.data.clone(),
            grad_theta,
            grad_z0,
            reconstructed_z0: Some(cur.z.data),
            stats,
            per_sample_fwd: fwd.per_sample,
        })
    }

    /// Multi-observation MALI: **one** continuous ψ⁻¹ reverse sweep over
    /// the whole accepted grid, injecting each observation's decoder
    /// cotangent as the sweep passes its `tᵢ` — evaluated at the
    /// ψ⁻¹-reconstructed state, so nothing beyond the augmented end state
    /// is retained between passes.  No per-segment re-initialisation of
    /// `v`: the constant-memory law `N_z(N_f + 1)` holds independently of
    /// both the step count and the number of observations K (asserted via
    /// `MemTracker` in the test suite).
    #[allow(clippy::too_many_arguments)]
    fn grad_obs(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        grid: &ObsGrid,
        z0: &[f32],
        loss: &dyn ObsLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<ObsGradResult> {
        ensure!(
            solver.is_invertible(),
            "MALI requires an invertible solver (ALF); '{}' has no ψ⁻¹",
            solver.name()
        );
        ensure!(
            !grid.is_empty(),
            "empty observation grid; use grad() for a terminal loss"
        );
        let c = dynamics.counters();
        c.reset();
        let mut ws = SolverWorkspace::new();

        // ---- forward: end state + accepted grid + observation marks ----
        let s0 = solver.init(dynamics, spec.t0, z0);
        let mut rec = GridRecorder::new(spec.t0);
        let fwd = integrate_obs_ws(
            solver, dynamics, spec.t0, spec.t1, &s0, &spec.mode, &spec.norm, grid, &mut rec,
            &mut ws,
        )?;
        let s_end = ws.take_output();
        let kept_z = TrackedBuf::new(s_end.z.clone(), tracker.clone());
        let kept_v = TrackedBuf::new(
            s_end.v.clone().expect("ALF state carries v"),
            tracker.clone(),
        );

        // ---- backward: continuous ψ⁻¹ sweep with injections ------------
        let mut cur = s_end;
        let mut a = State {
            z: vec![0.0f32; cur.z.len()],
            v: Some(vec![0.0f32; cur.z.len()]),
        };
        let mut prev = State {
            z: Vec::new(),
            v: None,
        };
        let mut a_prev = State {
            z: Vec::new(),
            v: None,
        };
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        let mut obs_losses = vec![0.0f64; grid.len()];
        let times = rec.times();
        let marks = rec.obs_marks();
        let n = times.len() - 1;
        let mut mp = marks.len();
        for i in (0..=n).rev() {
            while mp > 0 && marks[mp - 1].1 == i {
                let k = marks[mp - 1].0;
                let (l, g) = loss.loss_grad_at(k, grid.time(k), &cur.z);
                obs_losses[k] = l;
                axpy(1.0, &g, &mut a.z);
                mp -= 1;
            }
            if i == 0 {
                break;
            }
            let h = times[i] - times[i - 1];
            let ok = solver.invert_and_vjp_into(
                dynamics,
                times[i],
                h,
                &cur,
                &a,
                &mut prev,
                &mut a_prev,
                &mut grad_theta,
                &mut ws,
            );
            assert!(ok, "invertible solver");
            std::mem::swap(&mut cur, &mut prev);
            std::mem::swap(&mut a, &mut a_prev);
        }
        // final hop through v₀ = f(z₀, t₀)
        let mut grad_z0 = a.z.clone();
        if let Some(av0) = &a.v {
            if av0.iter().any(|&x| x != 0.0) {
                let (gz, gth) = dynamics.f_vjp(spec.t0, &cur.z, av0);
                axpy(1.0, &gz, &mut grad_z0);
                axpy(1.0, &gth, &mut grad_theta);
            }
        }

        let stats = GradStats {
            bwd_steps: n,
            f_evals: c.f_evals.get(),
            vjp_evals: c.vjp_evals.get(),
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * n.max(1),
            fwd,
        };
        Ok(ObsGradResult {
            loss: obs_losses.iter().sum(),
            obs_losses,
            z_final: kept_z.data.clone(),
            grad_theta,
            grad_z0,
            reconstructed_z0: Some(cur.z),
            stats,
        })
    }

    /// Batched multi-observation MALI: the lockstep ψ⁻¹ sweep of
    /// [`GradMethod::grad_batch`] with per-row cotangent injections at
    /// each row's observation marks — retained memory stays the flat
    /// augmented end state, `B·N_z(N_f + 1)`, independent of steps and K.
    #[allow(clippy::too_many_arguments)]
    fn grad_obs_batch(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        grid: &ObsGrid,
        z0: &[f32],
        bspec: &BatchSpec,
        loss: &dyn BatchObsLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<BatchObsGradResult> {
        ensure!(
            solver.is_invertible(),
            "MALI requires an invertible solver (ALF); '{}' has no ψ⁻¹",
            solver.name()
        );
        ensure!(
            !grid.is_empty(),
            "empty observation grid; use grad_batch() for a terminal loss"
        );
        ensure!(
            loss.separable(),
            "the batched ψ⁻¹ sweep injects per row (rows desynchronize); a \
             fused head must go through batch_driver::grad_obs_batched"
        );
        let c = dynamics.counters();
        let f0 = c.f_evals.get();
        let v0 = c.vjp_evals.get();
        let mut ws = BatchWorkspace::new();

        // ---- forward: end state + per-sample grids and marks -----------
        let s0 = solver.init_batch(dynamics, spec.t0, z0, bspec);
        let mut rec = BatchGridRecorder::new(spec.t0, bspec.batch);
        let fwd = integrate_batch_obs_ws(
            solver, dynamics, spec.t0, spec.t1, &s0, &spec.mode, &spec.norm, grid, &mut rec,
            &mut ws,
        )?;
        let s_end = ws.take_output();
        let kept_z = TrackedBuf::new(s_end.z.data.clone(), tracker.clone());
        let kept_v = TrackedBuf::new(
            s_end.v.as_ref().expect("ALF state carries v").data.clone(),
            tracker.clone(),
        );

        // ---- backward: lockstep ψ⁻¹ sweep with per-row injections ------
        let mut cur = s_end;
        let mut a = BatchState::from_flat_zv(
            vec![0.0f32; bspec.flat_len()],
            vec![0.0f32; bspec.flat_len()],
            *bspec,
        );
        let mut prev = ws.take_batch(bspec.batch, bspec.n_z, true);
        let mut a_prev = ws.take_batch(bspec.batch, bspec.n_z, true);
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        let mut obs_losses = vec![0.0f64; grid.len()];
        let row_spec = BatchSpec::single(bspec.n_z);
        let mut rem: Vec<usize> = rec.times.iter().map(|t| t.len() - 1).collect();
        let mut mp: Vec<usize> = rec.obs_marks.iter().map(|m| m.len()).collect();
        let mut ts_out: Vec<f64> = Vec::new();
        let mut hs: Vec<f64> = Vec::new();
        loop {
            // inject the cotangents due at each row's current position,
            // evaluated at the ψ⁻¹-reconstructed row
            for b in 0..bspec.batch {
                while mp[b] > 0 && rec.obs_marks[b][mp[b] - 1].1 == rem[b] {
                    let k = rec.obs_marks[b][mp[b] - 1].0;
                    let (ls, g) = loss.loss_grad_at_batch(
                        k,
                        grid.time(k),
                        bspec.row(&cur.z.data, b),
                        &row_spec,
                    );
                    obs_losses[k] += ls.iter().sum::<f64>();
                    axpy(1.0, &g, bspec.row_mut(&mut a.z.data, b));
                    mp[b] -= 1;
                }
            }
            let active: Vec<usize> = (0..bspec.batch).filter(|&b| rem[b] > 0).collect();
            if active.is_empty() {
                break;
            }
            ts_out.clear();
            ts_out.extend(active.iter().map(|&b| rec.times[b][rem[b]]));
            hs.clear();
            hs.extend(
                active
                    .iter()
                    .map(|&b| rec.times[b][rem[b]] - rec.times[b][rem[b] - 1]),
            );
            let full = active.len() == bspec.batch;
            if full {
                let ok = solver.invert_and_vjp_batch_into(
                    dynamics,
                    &ts_out,
                    &hs,
                    &cur,
                    &a,
                    &mut prev,
                    &mut a_prev,
                    &mut grad_theta,
                    &mut ws,
                );
                assert!(ok, "invertible solver");
                std::mem::swap(&mut cur, &mut prev);
                std::mem::swap(&mut a, &mut a_prev);
            } else {
                let cur_sub = cur.gather_rows(&active);
                let a_sub = a.gather_rows(&active);
                let (prev_sub, a_prev_sub, dth) = solver
                    .invert_and_vjp_batch(dynamics, &ts_out, &hs, &cur_sub, &a_sub)
                    .expect("invertible solver");
                axpy(1.0, &dth, &mut grad_theta);
                cur.scatter_rows(&prev_sub, &active);
                a.scatter_rows(&a_prev_sub, &active);
            }
            for &b in &active {
                rem[b] -= 1;
            }
        }

        // final hop through v₀ = f(z₀, t₀) at the reconstructed rows
        let mut grad_z0 = a.z.data.clone();
        super::aca::init_hop_batch(
            dynamics,
            spec.t0,
            &cur.z.data,
            bspec,
            &a,
            &mut grad_z0,
            &mut grad_theta,
        );

        let n_total: usize = rec.times.iter().map(|t| t.len() - 1).sum();
        let n_max: usize = rec.times.iter().map(|t| t.len() - 1).max().unwrap_or(0);
        let stats = GradStats {
            bwd_steps: n_total,
            f_evals: c.f_evals.get() - f0,
            vjp_evals: c.vjp_evals.get() - v0,
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * n_max.max(1),
            fwd: fwd.aggregate(),
        };
        Ok(BatchObsGradResult {
            batch: bspec.batch,
            n_z: bspec.n_z,
            loss: obs_losses.iter().sum(),
            obs_losses,
            z_final: kept_z.data.clone(),
            grad_theta,
            grad_z0,
            reconstructed_z0: Some(cur.z.data),
            stats,
            per_sample_fwd: fwd.per_sample,
        })
    }
}
