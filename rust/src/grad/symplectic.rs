//! Symplectic adjoint — Matsubara et al. (NeurIPS 2021), the fifth
//! gradient protocol.
//!
//! Forward: checkpoint the accepted trajectory exactly like ACA.
//! Backward: integrate the adjoint system *in reverse* through the same
//! discrete map the forward used — `step_vjp` of one forward step is one
//! reverse step of the discrete adjoint system, and when the solver is
//! symplectic/time-symmetric (ALF at η = 1, or the reversible-4
//! composition) that reverse sweep is itself a symplectic integration of
//! the continuous adjoint flow, which is Matsubara et al.'s observation.
//! For a non-symmetric solver (RK) the method degrades gracefully to a
//! checkpointed discrete adjoint — still *exact* to roundoff, just
//! without the symplectic-conjugacy structure.
//!
//! The memory law is the part that differs from ACA: each checkpoint is
//! **consumed** by the backward sweep (popped and released the moment its
//! local vjp has been taken), so live checkpoint memory *decreases*
//! linearly during the reverse pass instead of staying flat until the
//! end.  The peak is still the full checkpoint store `N_z·N_t` plus one
//! step's stage scratch — Matsubara's `O(N_z·N_t + stage)` bound, sitting
//! between ACA (`N_z(N_f + N_t)`, holds all local graphs' inputs AND the
//! tape) and MALI (`N_z(N_f + 1)`, constant in steps).  The MemTracker
//! assertions in `tests/grad_methods.rs` pin peak ≤ the ACA bound and the
//! monotone release.
//!
//! Gradients are bit-for-bit the ACA sequence (same `step_vjp` chain over
//! the same accepted steps), so this method joins the
//! `mali ≡ aca ≡ naive ≡ symplectic` exact-agreement set in
//! `tests/prop_grad.rs`.

use super::aca::{
    init_hop_batch, replay_backward_batch, replay_backward_batch_obs, replay_backward_obs,
};
use super::{
    BatchGradResult, BatchLossHead, BatchObsGradResult, BatchObsLossHead, GradMethod, GradResult,
    GradStats, IvpSpec, LossHead, ObsGrid, ObsGradResult, ObsLossHead,
};
use crate::solvers::batch::{BatchSpec, BatchState};
use crate::solvers::dynamics::Dynamics;
use crate::solvers::integrate::{
    integrate, integrate_batch, integrate_batch_obs, integrate_obs, AcceptedStep,
    BatchAcceptedStep, BatchStepObserver, StepObserver,
};
use crate::solvers::workspace::{BatchWorkspace, SolverWorkspace};
use crate::solvers::{Solver, State};
use crate::tensor::axpy;
use crate::util::mem::{MemTracker, TrackedBuf};
use anyhow::{ensure, Result};
use std::sync::Arc;

pub struct SymplecticAdjoint;

/// Checkpoint tape: `(t, h, state-before)` per accepted step plus the
/// observation marks, with the tracked byte accounting kept *per step* so
/// the backward sweep can release each checkpoint as it consumes it.
struct Tape {
    tracker: Arc<MemTracker>,
    steps: Vec<(f64, f64, State)>,
    marks: Vec<(usize, usize)>,
    bufs: Vec<TrackedBuf>,
}

impl Tape {
    fn new(tracker: Arc<MemTracker>) -> Self {
        Tape {
            tracker,
            steps: Vec::new(),
            marks: Vec::new(),
            bufs: Vec::new(),
        }
    }

    /// Release the tracked bytes of the most recent still-held checkpoint
    /// (z and, for augmented states, v).
    fn release_last(&mut self, had_v: bool) {
        self.bufs.pop();
        if had_v {
            self.bufs.pop();
        }
    }
}

impl StepObserver for Tape {
    fn on_accept(&mut self, step: &AcceptedStep) {
        self.bufs.push(TrackedBuf::new(
            step.before.z.clone(),
            self.tracker.clone(),
        ));
        if let Some(v) = &step.before.v {
            self.bufs
                .push(TrackedBuf::new(v.clone(), self.tracker.clone()));
        }
        self.steps.push((step.t, step.h, step.before.clone()));
    }

    fn on_observation(&mut self, k: usize, _t: f64, _state: &State) {
        self.marks.push((k, self.steps.len()));
    }
}

/// Batched tape: one step list per sample (the lockstep replay shared
/// with ACA walks them), tracked bytes held until the replay finishes.
struct BatchTape {
    tracker: Arc<MemTracker>,
    steps: Vec<Vec<(f64, f64, State)>>,
    marks: Vec<Vec<(usize, usize)>>,
    bufs: Vec<TrackedBuf>,
}

impl BatchTape {
    fn new(tracker: Arc<MemTracker>, batch: usize) -> Self {
        BatchTape {
            tracker,
            steps: vec![Vec::new(); batch],
            marks: vec![Vec::new(); batch],
            bufs: Vec::new(),
        }
    }
}

impl BatchStepObserver for BatchTape {
    fn on_accept(&mut self, step: &BatchAcceptedStep) {
        let before = step.before_state();
        self.bufs
            .push(TrackedBuf::new(before.z.clone(), self.tracker.clone()));
        if let Some(v) = &before.v {
            self.bufs
                .push(TrackedBuf::new(v.clone(), self.tracker.clone()));
        }
        self.steps[step.sample].push((step.t, step.h, before));
    }

    fn on_observation(&mut self, sample: usize, k: usize, _t: f64, _z: &[f32], _v: Option<&[f32]>) {
        self.marks[sample].push((k, self.steps[sample].len()));
    }
}

impl GradMethod for SymplecticAdjoint {
    fn name(&self) -> &'static str {
        "symplectic"
    }

    fn grad(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        z0: &[f32],
        loss: &dyn LossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<GradResult> {
        let c = dynamics.counters();
        c.reset();

        // ---- forward with checkpointing ---------------------------------
        let s0 = solver.init(dynamics, spec.t0, z0);
        let mut tape = Tape::new(tracker.clone());
        let (s_end, fwd) = integrate(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, &mut tape,
        )?;
        let (loss_val, dl_dz) = loss.loss_grad(&s_end.z);
        let n = tape.steps.len();

        // ---- backward: reverse adjoint sweep, consuming the tape --------
        let mut ws = SolverWorkspace::new();
        let mut a = State {
            z: dl_dz,
            v: s_end.v.as_ref().map(|v| vec![0.0f32; v.len()]),
        };
        let mut a_prev = ws.take_state(&a);
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        while let Some((t, h, before)) = tape.steps.pop() {
            solver.step_vjp_into(dynamics, t, h, &before, &a, &mut a_prev, &mut grad_theta, &mut ws);
            std::mem::swap(&mut a, &mut a_prev);
            // the checkpoint has served its one local vjp — release it
            tape.release_last(before.v.is_some());
        }
        ws.put_state(a_prev);
        // initialisation hop (the tape is drained, but the first step's
        // stored input state *is* z₀, so evaluating at z₀ is exact)
        let mut grad_z0 = a.z.clone();
        if let Some(av0) = &a.v {
            if av0.iter().any(|&x| x != 0.0) {
                let (gz, gth) = dynamics.f_vjp(spec.t0, z0, av0);
                axpy(1.0, &gz, &mut grad_z0);
                axpy(1.0, &gth, &mut grad_theta);
            }
        }

        let stats = GradStats {
            bwd_steps: n,
            f_evals: c.f_evals.get(),
            vjp_evals: c.vjp_evals.get(),
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * n.max(1),
            fwd,
        };
        Ok(GradResult {
            loss: loss_val,
            z_final: s_end.z,
            grad_theta,
            grad_z0,
            reconstructed_z0: None,
            stats,
        })
    }

    /// Batched symplectic adjoint: per-sample tapes, then the lockstep
    /// reverse sweep shared with ACA (rows in the lockstep replay consume
    /// their checkpoints at different rates, so the per-step release is
    /// deferred to the end of the sweep — the peak is identical either
    /// way, since the peak is at the start of the backward pass).
    #[allow(clippy::too_many_arguments)]
    fn grad_batch(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        z0: &[f32],
        bspec: &BatchSpec,
        loss: &dyn BatchLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<BatchGradResult> {
        let c = dynamics.counters();
        let f0 = c.f_evals.get();
        let v0 = c.vjp_evals.get();

        let s0 = solver.init_batch(dynamics, spec.t0, z0, bspec);
        let mut tape = BatchTape::new(tracker.clone(), bspec.batch);
        let (s_end, fwd) = integrate_batch(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, &mut tape,
        )?;
        let (losses, dl_dz) = loss.loss_grad_batch(&s_end.z.data, bspec);

        let mut a = BatchState {
            z: crate::tensor::Tensor::new(dl_dz, vec![bspec.batch, bspec.n_z]),
            v: s_end
                .v
                .as_ref()
                .map(|v| crate::tensor::Tensor::zeros(&v.shape)),
        };
        let mut ws = BatchWorkspace::new();
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        replay_backward_batch(dynamics, solver, &tape.steps, &mut a, &mut grad_theta, &mut ws);

        let mut grad_z0 = a.z.data.clone();
        init_hop_batch(dynamics, spec.t0, z0, bspec, &a, &mut grad_z0, &mut grad_theta);

        let n_total: usize = tape.steps.iter().map(|s| s.len()).sum();
        let n_max: usize = tape.steps.iter().map(|s| s.len()).max().unwrap_or(0);
        let stats = GradStats {
            bwd_steps: n_total,
            f_evals: c.f_evals.get() - f0,
            vjp_evals: c.vjp_evals.get() - v0,
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * n_max.max(1),
            fwd: fwd.aggregate(),
        };
        Ok(BatchGradResult {
            batch: bspec.batch,
            n_z: bspec.n_z,
            loss: losses.iter().sum(),
            losses,
            z_final: s_end.z.data,
            grad_theta,
            grad_z0,
            reconstructed_z0: None,
            stats,
            per_sample_fwd: fwd.per_sample,
        })
    }

    /// Multi-observation symplectic adjoint: checkpointed forward over the
    /// exact-hit grid, then the shared injection replay (observation
    /// cotangents join the adjoint state as it sweeps past their marks —
    /// for a symplectic solver these are the impulse terms of the adjoint
    /// flow).
    #[allow(clippy::too_many_arguments)]
    fn grad_obs(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        grid: &ObsGrid,
        z0: &[f32],
        loss: &dyn ObsLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<ObsGradResult> {
        ensure!(
            !grid.is_empty(),
            "empty observation grid; use grad() for a terminal loss"
        );
        let c = dynamics.counters();
        c.reset();

        let s0 = solver.init(dynamics, spec.t0, z0);
        let mut tape = Tape::new(tracker.clone());
        let (s_end, fwd) = integrate_obs(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, grid, &mut tape,
        )?;

        let mut a = State {
            z: vec![0.0f32; s_end.z.len()],
            v: s_end.v.as_ref().map(|v| vec![0.0f32; v.len()]),
        };
        let mut ws = SolverWorkspace::new();
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        let mut obs_losses = vec![0.0f64; grid.len()];
        replay_backward_obs(
            dynamics,
            solver,
            &tape.steps,
            &tape.marks,
            grid,
            &s_end.z,
            loss,
            &mut a,
            &mut grad_theta,
            &mut obs_losses,
            &mut ws,
        );
        let mut grad_z0 = a.z.clone();
        if let Some(av0) = &a.v {
            if av0.iter().any(|&x| x != 0.0) {
                let first_z = tape
                    .steps
                    .first()
                    .map(|(_, _, s)| s.z.as_slice())
                    .unwrap_or(z0);
                let (gz, gth) = dynamics.f_vjp(spec.t0, first_z, av0);
                axpy(1.0, &gz, &mut grad_z0);
                axpy(1.0, &gth, &mut grad_theta);
            }
        }

        let n = tape.steps.len();
        let stats = GradStats {
            bwd_steps: n,
            f_evals: c.f_evals.get(),
            vjp_evals: c.vjp_evals.get(),
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * n.max(1),
            fwd,
        };
        Ok(ObsGradResult {
            loss: obs_losses.iter().sum(),
            obs_losses,
            z_final: s_end.z,
            grad_theta,
            grad_z0,
            reconstructed_z0: None,
            stats,
        })
    }

    /// Batched multi-observation symplectic adjoint: per-sample tapes +
    /// marks into the shared lockstep injection replay.
    #[allow(clippy::too_many_arguments)]
    fn grad_obs_batch(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        grid: &ObsGrid,
        z0: &[f32],
        bspec: &BatchSpec,
        loss: &dyn BatchObsLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<BatchObsGradResult> {
        ensure!(
            !grid.is_empty(),
            "empty observation grid; use grad_batch() for a terminal loss"
        );
        ensure!(
            loss.separable(),
            "batched native injection evaluates the head per row; a fused \
             head must go through batch_driver::grad_obs_batched"
        );
        let c = dynamics.counters();
        let f0 = c.f_evals.get();
        let v0 = c.vjp_evals.get();

        let s0 = solver.init_batch(dynamics, spec.t0, z0, bspec);
        let mut tape = BatchTape::new(tracker.clone(), bspec.batch);
        let (s_end, fwd) = integrate_batch_obs(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, grid, &mut tape,
        )?;

        let mut a = BatchState {
            z: crate::tensor::Tensor::zeros(&[bspec.batch, bspec.n_z]),
            v: s_end
                .v
                .as_ref()
                .map(|v| crate::tensor::Tensor::zeros(&v.shape)),
        };
        let mut ws = BatchWorkspace::new();
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        let mut obs_losses = vec![0.0f64; grid.len()];
        replay_backward_batch_obs(
            dynamics,
            solver,
            &tape.steps,
            &tape.marks,
            grid,
            &s_end.z.data,
            loss,
            &mut a,
            &mut grad_theta,
            &mut obs_losses,
            &mut ws,
        );

        let mut grad_z0 = a.z.data.clone();
        init_hop_batch(dynamics, spec.t0, z0, bspec, &a, &mut grad_z0, &mut grad_theta);

        let n_total: usize = tape.steps.iter().map(|s| s.len()).sum();
        let n_max: usize = tape.steps.iter().map(|s| s.len()).max().unwrap_or(0);
        let stats = GradStats {
            bwd_steps: n_total,
            f_evals: c.f_evals.get() - f0,
            vjp_evals: c.vjp_evals.get() - v0,
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * n_max.max(1),
            fwd: fwd.aggregate(),
        };
        Ok(BatchObsGradResult {
            batch: bspec.batch,
            n_z: bspec.n_z,
            loss: obs_losses.iter().sum(),
            obs_losses,
            z_final: s_end.z.data,
            grad_theta,
            grad_z0,
            reconstructed_z0: None,
            stats,
            per_sample_fwd: fwd.per_sample,
        })
    }
}
