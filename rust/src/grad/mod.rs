//! Gradient estimation for Neural ODEs — the paper's subject.
//!
//! Four protocols compute `dL/dθ` and `dL/dz₀` for
//! `L = loss(z(T))`, `dz/dt = f(t, z; θ)`, `z(t₀) = z₀`:
//!
//! | method   | module       | trajectory for backward        | memory (Table 1)      |
//! |----------|--------------|--------------------------------|-----------------------|
//! | naive    | [`naive`]    | full tape incl. rejected trials| `N_z·N_f·N_t·m`       |
//! | adjoint  | [`adjoint`]  | re-solved reverse-time IVP     | `N_z·N_f`             |
//! | ACA      | [`aca`]      | checkpoints of accepted steps  | `N_z(N_f + N_t)`      |
//! | **MALI** | [`mali`]     | ψ⁻¹-reconstructed (exact)      | `N_z(N_f + 1)`        |
//!
//! All four share the [`Solver`]/[`Dynamics`] abstractions, report
//! [`GradStats`] (measured memory, evaluations, graph depth) and are
//! interchangeable in the trainer — exactly how the paper swaps them across
//! experiments.

pub mod aca;
pub mod adjoint;
pub mod mali;
pub mod naive;

use crate::solvers::dynamics::Dynamics;
use crate::solvers::integrate::{ErrorNorm, IntStats, StepMode};
use crate::solvers::Solver;
use crate::util::mem::MemTracker;
use anyhow::Result;
use std::sync::Arc;

/// Loss head: maps the terminal state to `(loss, ∂L/∂z_T)`.
pub trait LossHead {
    /// Evaluate the loss and its gradient w.r.t. the terminal state `z(T)`.
    fn loss_grad(&self, z_t: &[f32]) -> (f64, Vec<f32>);
}

/// Closure adapter so tests and examples can pass lambdas.
pub struct FnLoss<F: Fn(&[f32]) -> (f64, Vec<f32>)>(pub F);

impl<F: Fn(&[f32]) -> (f64, Vec<f32>)> LossHead for FnLoss<F> {
    fn loss_grad(&self, z_t: &[f32]) -> (f64, Vec<f32>) {
        (self.0)(z_t)
    }
}

/// Sum-of-squares loss `L = Σ z_i²` — the paper's toy objective (Eq. 6).
pub struct SquareLoss;

impl LossHead for SquareLoss {
    fn loss_grad(&self, z_t: &[f32]) -> (f64, Vec<f32>) {
        let loss: f64 = z_t.iter().map(|&z| (z as f64) * (z as f64)).sum();
        let grad = z_t.iter().map(|&z| 2.0 * z).collect();
        (loss, grad)
    }
}

/// Shared configuration of one gradient computation.
#[derive(Debug, Clone)]
pub struct IvpSpec {
    /// Integration start time.
    pub t0: f64,
    /// Integration end time (may be < `t0` for reverse-time solves).
    pub t1: f64,
    /// Step-size policy (fixed or adaptive).
    pub mode: StepMode,
    /// Error-norm selection for the adaptive controller.
    pub norm: ErrorNorm,
}

impl IvpSpec {
    /// Fixed-step IVP over `[t0, t1]` with step magnitude `h`.
    pub fn fixed(t0: f64, t1: f64, h: f64) -> IvpSpec {
        IvpSpec {
            t0,
            t1,
            mode: StepMode::Fixed { h },
            norm: ErrorNorm::Full,
        }
    }

    /// Adaptive-step IVP over `[t0, t1]` with the given tolerances.
    pub fn adaptive(t0: f64, t1: f64, rtol: f64, atol: f64) -> IvpSpec {
        IvpSpec {
            t0,
            t1,
            mode: StepMode::adaptive(rtol, atol),
            norm: ErrorNorm::Full,
        }
    }
}

/// Measured cost/fidelity statistics of one gradient computation — the
/// empirical side of paper Table 1.
#[derive(Debug, Clone, Default)]
pub struct GradStats {
    /// Forward-pass integration statistics (accepted steps, trials, evals).
    pub fwd: IntStats,
    /// Backward-pass solver steps (reverse IVP steps for adjoint; local
    /// replays for the others).
    pub bwd_steps: usize,
    /// Total `f` evaluations (forward + backward), including those inside
    /// vjp computations.
    pub f_evals: u64,
    pub vjp_evals: u64,
    /// Peak bytes of retained solver state (checkpoints/tapes) — the
    /// quantity paper Fig. 4(c) plots.
    pub peak_mem_bytes: usize,
    /// Longest chain of `f`-applications any gradient flows through
    /// (`N_f × N_t` for ACA/MALI, `N_f × N_t × m` for naive).
    pub graph_depth: usize,
}

/// Result of one gradient computation.
#[derive(Debug, Clone)]
pub struct GradResult {
    /// Loss value at the terminal state.
    pub loss: f64,
    /// Terminal state `z(T)` of the forward solve.
    pub z_final: Vec<f32>,
    /// `dL/dθ` over the dynamics parameters.
    pub grad_theta: Vec<f32>,
    /// `dL/dz₀` over the initial state.
    pub grad_z0: Vec<f32>,
    /// Adjoint method only: its reconstruction ẑ(t₀) of the initial state —
    /// the reverse-time-trajectory error the paper analyses (Thm. 2.1).
    pub reconstructed_z0: Option<Vec<f32>>,
    /// Measured cost statistics (paper Table 1, empirically).
    pub stats: GradStats,
}

/// One gradient-estimation protocol.
pub trait GradMethod {
    /// Stable identifier used in configs, CLI flags and report tables.
    fn name(&self) -> &'static str;

    /// Compute loss and gradients for the IVP.  `tracker` receives every
    /// buffer the method retains between forward and backward.
    fn grad(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        z0: &[f32],
        loss: &dyn LossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<GradResult>;
}

/// Method construction by config/CLI name.
pub fn by_name(name: &str) -> Result<Box<dyn GradMethod>> {
    Ok(match name {
        "mali" => Box::new(mali::Mali),
        "aca" => Box::new(aca::Aca),
        "naive" => Box::new(naive::Naive),
        "adjoint" => Box::new(adjoint::Adjoint::default()),
        "adjoint-seminorm" | "seminorm" => Box::new(adjoint::Adjoint { seminorm: true }),
        other => anyhow::bail!("unknown gradient method '{other}'"),
    })
}

/// The forward-only pass (inference): integrate and apply the loss head.
pub fn forward_loss(
    dynamics: &dyn Dynamics,
    solver: &dyn Solver,
    spec: &IvpSpec,
    z0: &[f32],
    loss: &dyn LossHead,
) -> Result<(f64, Vec<f32>, IntStats)> {
    let s0 = solver.init(dynamics, spec.t0, z0);
    let (sf, stats) = crate::solvers::integrate::integrate(
        solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, &mut (),
    )?;
    let (l, _) = loss.loss_grad(&sf.z);
    Ok((l, sf.z, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_loss_grad() {
        let (l, g) = SquareLoss.loss_grad(&[1.0, -2.0]);
        assert_eq!(l, 5.0);
        assert_eq!(g, vec![2.0, -4.0]);
    }

    #[test]
    fn factory_covers_methods() {
        for m in ["mali", "aca", "naive", "adjoint", "seminorm"] {
            assert!(by_name(m).is_ok(), "{m}");
        }
        assert!(by_name("bogus").is_err());
    }
}
