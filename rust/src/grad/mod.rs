//! Gradient estimation for Neural ODEs — the paper's subject.
//!
//! Four protocols compute `dL/dθ` and `dL/dz₀` for
//! `L = loss(z(T))`, `dz/dt = f(t, z; θ)`, `z(t₀) = z₀`:
//!
//! | method   | module       | trajectory for backward        | memory (Table 1)      |
//! |----------|--------------|--------------------------------|-----------------------|
//! | naive    | [`naive`]    | full tape incl. rejected trials| `N_z·N_f·N_t·m`       |
//! | adjoint  | [`adjoint`]  | re-solved reverse-time IVP     | `N_z·N_f`             |
//! | ACA      | [`aca`]      | checkpoints of accepted steps  | `N_z(N_f + N_t)`      |
//! | symplectic | [`symplectic`] | checkpoints, released as consumed | `N_z·N_t + stage` |
//! | **MALI** | [`mali`]     | ψ⁻¹-reconstructed (exact)      | `N_z(N_f + 1)`        |
//!
//! All four share the [`Solver`]/[`Dynamics`] abstractions, report
//! [`GradStats`] (measured memory, evaluations, graph depth) and are
//! interchangeable in the trainer — exactly how the paper swaps them across
//! experiments.
//!
//! # Observation grids
//!
//! The paper's time-series workloads (latent ODE, Neural CDE) attach a
//! loss at *many* observation times `t₁ … t_K`, not just the endpoint.
//! [`GradMethod::grad_obs`] / [`GradMethod::grad_obs_batch`] compute
//! `dL/dθ` and `dL/dz₀` for `L = Σ_k l_k(z(t_k))` in **one** pass per
//! method, with each method keeping its Table-1 signature:
//!
//! * **MALI** — one continuous ψ⁻¹ reverse sweep injecting each `∂l_k/∂z`
//!   at `t_k` (evaluated at the ψ⁻¹-reconstructed state), memory constant
//!   in both the step count and K;
//! * **adjoint** — one reverse augmented IVP with cotangent jump
//!   discontinuities at each `t_k` (Chen et al. 2018), the `ẑ` block
//!   re-anchored to the stored forward observation states;
//! * **naive** — a single full tape with cotangent injections at the
//!   observation marks;
//! * **ACA** — the per-segment checkpoint structure behind the same
//!   interface: checkpoints of the accepted steps (segments share their
//!   boundaries with the exact-hit grid) replayed with injections.

pub mod aca;
pub mod adjoint;
pub mod batch_driver;
pub mod mali;
pub mod naive;
pub mod symplectic;

use crate::solvers::batch::BatchSpec;
use crate::solvers::dynamics::Dynamics;
use crate::solvers::integrate::{ErrorNorm, IntStats, StepMode};
use crate::solvers::Solver;
use crate::util::mem::MemTracker;
use anyhow::Result;
use std::sync::Arc;

pub use crate::solvers::integrate::ObsGrid;

/// Loss head: maps the terminal state to `(loss, ∂L/∂z_T)`.
pub trait LossHead {
    /// Evaluate the loss and its gradient w.r.t. the terminal state `z(T)`.
    fn loss_grad(&self, z_t: &[f32]) -> (f64, Vec<f32>);
}

/// Closure adapter so tests and examples can pass lambdas.
pub struct FnLoss<F: Fn(&[f32]) -> (f64, Vec<f32>)>(pub F);

impl<F: Fn(&[f32]) -> (f64, Vec<f32>)> LossHead for FnLoss<F> {
    fn loss_grad(&self, z_t: &[f32]) -> (f64, Vec<f32>) {
        (self.0)(z_t)
    }
}

/// Sum-of-squares loss `L = Σ z_i²` — the paper's toy objective (Eq. 6).
pub struct SquareLoss;

impl LossHead for SquareLoss {
    fn loss_grad(&self, z_t: &[f32]) -> (f64, Vec<f32>) {
        let loss: f64 = z_t.iter().map(|&z| (z as f64) * (z as f64)).sum();
        let grad = z_t.iter().map(|&z| 2.0 * z).collect();
        (loss, grad)
    }
}

/// Loss head over a `[B, N_z]` batch of terminal states.
///
/// Returns per-sample losses plus the flat `dL/dz_T` buffer.  Heads that
/// are not separable per row — e.g. the image model's fused device call
/// computing the batch-summed cross entropy — may return a single total
/// in the loss vector; the batch total is always `losses.iter().sum()`.
///
/// Every [`LossHead`] is automatically a `BatchLossHead` applied row by
/// row (the separable case), so `SquareLoss` and `FnLoss` work unchanged.
pub trait BatchLossHead {
    fn loss_grad_batch(&self, z_t: &[f32], spec: &BatchSpec) -> (Vec<f64>, Vec<f32>);

    /// `true` when the head decomposes per row (evaluating it on any
    /// sub-batch of rows is exact) — required by the pooled batch driver,
    /// which shards rows across workers.  Non-separable heads (one fused
    /// device call over the whole batch) must return `false` so sharding
    /// fails loudly instead of computing a silently wrong loss.
    fn separable(&self) -> bool {
        true
    }
}

impl<L: LossHead + ?Sized> BatchLossHead for L {
    fn loss_grad_batch(&self, z_t: &[f32], spec: &BatchSpec) -> (Vec<f64>, Vec<f32>) {
        let mut losses = Vec::with_capacity(spec.batch);
        let mut grad = Vec::with_capacity(z_t.len());
        for b in 0..spec.batch {
            let (l, g) = self.loss_grad(spec.row(z_t, b));
            losses.push(l);
            grad.extend_from_slice(&g);
        }
        (losses, grad)
    }
}

/// Per-observation loss head: maps the state at observation `k` of an
/// [`ObsGrid`] to `(l_k, ∂l_k/∂z(t_k))`.  The total objective is
/// `L = Σ_k l_k(z(t_k))` — the shape of every time-series loss in the
/// paper (per-frame MSE, per-observation likelihoods).
pub trait ObsLossHead {
    fn loss_grad_at(&self, k: usize, t: f64, z: &[f32]) -> (f64, Vec<f32>);
}

/// Closure adapter so models and tests can pass lambdas as observation
/// heads (the multi-observation analogue of [`FnLoss`]).
pub struct FnObsLoss<F: Fn(usize, f64, &[f32]) -> (f64, Vec<f32>)>(pub F);

impl<F: Fn(usize, f64, &[f32]) -> (f64, Vec<f32>)> ObsLossHead for FnObsLoss<F> {
    fn loss_grad_at(&self, k: usize, t: f64, z: &[f32]) -> (f64, Vec<f32>) {
        (self.0)(k, t, z)
    }
}

/// `l_k = w_k · Σ z_i²` — [`SquareLoss`] attached at every observation
/// with per-observation weights; the toy multi-observation objective of
/// the tests and benches.  Missing weights default to 1.
pub struct ObsSquareLoss {
    pub weights: Vec<f64>,
}

impl ObsLossHead for ObsSquareLoss {
    fn loss_grad_at(&self, k: usize, _t: f64, z: &[f32]) -> (f64, Vec<f32>) {
        let w = self.weights.get(k).copied().unwrap_or(1.0);
        let (l, mut g) = SquareLoss.loss_grad(z);
        for gi in &mut g {
            *gi *= w as f32;
        }
        (l * w, g)
    }
}

/// Per-observation loss head over a `[B, N_z]` batch of states at `t_k`.
///
/// Mirrors [`BatchLossHead`]: separable heads decompose per row (every
/// [`ObsLossHead`] is one, applied row-wise, via the blanket impl);
/// non-separable heads — one fused device call over the whole batch, like
/// the latent-ODE decoder — return a single total per observation and
/// must set [`BatchObsLossHead::separable`] to `false` so row-sharding
/// paths fail loudly.
pub trait BatchObsLossHead {
    fn loss_grad_at_batch(&self, k: usize, t: f64, z: &[f32], spec: &BatchSpec)
        -> (Vec<f64>, Vec<f32>);

    /// `true` when the head decomposes per row — see [`BatchLossHead::separable`].
    fn separable(&self) -> bool {
        true
    }
}

impl<L: ObsLossHead + ?Sized> BatchObsLossHead for L {
    fn loss_grad_at_batch(
        &self,
        k: usize,
        t: f64,
        z: &[f32],
        spec: &BatchSpec,
    ) -> (Vec<f64>, Vec<f32>) {
        let mut losses = Vec::with_capacity(spec.batch);
        let mut grad = Vec::with_capacity(z.len());
        for b in 0..spec.batch {
            let (l, g) = self.loss_grad_at(k, t, spec.row(z, b));
            losses.push(l);
            grad.extend_from_slice(&g);
        }
        (losses, grad)
    }
}

/// Closure adapter for **fused** (non-separable) batch observation heads:
/// the closure sees the whole flat `[B·N_z]` buffer at `t_k` in one call
/// — the device-executable pattern of the latent-ODE decoder and the CDE
/// classification head.
pub struct FusedObsLoss<F: Fn(usize, f64, &[f32]) -> (f64, Vec<f32>)>(pub F);

impl<F: Fn(usize, f64, &[f32]) -> (f64, Vec<f32>)> BatchObsLossHead for FusedObsLoss<F> {
    fn loss_grad_at_batch(
        &self,
        k: usize,
        t: f64,
        z: &[f32],
        _spec: &BatchSpec,
    ) -> (Vec<f64>, Vec<f32>) {
        let (l, g) = (self.0)(k, t, z);
        (vec![l], g)
    }

    fn separable(&self) -> bool {
        false
    }
}

/// Shared configuration of one gradient computation.
#[derive(Debug, Clone)]
pub struct IvpSpec {
    /// Integration start time.
    pub t0: f64,
    /// Integration end time (may be < `t0` for reverse-time solves).
    pub t1: f64,
    /// Step-size policy (fixed or adaptive).
    pub mode: StepMode,
    /// Error-norm selection for the adaptive controller.
    pub norm: ErrorNorm,
}

impl IvpSpec {
    /// Fixed-step IVP over `[t0, t1]` with step magnitude `h`.
    pub fn fixed(t0: f64, t1: f64, h: f64) -> IvpSpec {
        IvpSpec {
            t0,
            t1,
            mode: StepMode::Fixed { h },
            norm: ErrorNorm::Full,
        }
    }

    /// Adaptive-step IVP over `[t0, t1]` with the given tolerances.
    pub fn adaptive(t0: f64, t1: f64, rtol: f64, atol: f64) -> IvpSpec {
        IvpSpec {
            t0,
            t1,
            mode: StepMode::adaptive(rtol, atol),
            norm: ErrorNorm::Full,
        }
    }
}

/// Measured cost/fidelity statistics of one gradient computation — the
/// empirical side of paper Table 1.
#[derive(Debug, Clone, Default)]
pub struct GradStats {
    /// Forward-pass integration statistics (accepted steps, trials, evals).
    pub fwd: IntStats,
    /// Backward-pass solver steps (reverse IVP steps for adjoint; local
    /// replays for the others).
    pub bwd_steps: usize,
    /// Total `f` evaluations (forward + backward), including those inside
    /// vjp computations.
    pub f_evals: u64,
    pub vjp_evals: u64,
    /// Peak bytes of retained solver state (checkpoints/tapes) — the
    /// quantity paper Fig. 4(c) plots.
    pub peak_mem_bytes: usize,
    /// Longest chain of `f`-applications any gradient flows through
    /// (`N_f × N_t` for ACA/MALI, `N_f × N_t × m` for naive).
    pub graph_depth: usize,
}

/// Result of one gradient computation.
#[derive(Debug, Clone)]
pub struct GradResult {
    /// Loss value at the terminal state.
    pub loss: f64,
    /// Terminal state `z(T)` of the forward solve.
    pub z_final: Vec<f32>,
    /// `dL/dθ` over the dynamics parameters.
    pub grad_theta: Vec<f32>,
    /// `dL/dz₀` over the initial state.
    pub grad_z0: Vec<f32>,
    /// The backward pass's reconstruction ẑ(t₀) of the initial state,
    /// populated by the two methods that rebuild the reverse trajectory:
    /// the **adjoint** method (its re-solved reverse IVP — the error source
    /// paper Thm. 2.1 analyses) and **MALI** (its ψ⁻¹ sweep, exact to float
    /// roundoff — paper §3.2).  `None` for naive/ACA, which replay stored
    /// states instead of reconstructing them.
    pub reconstructed_z0: Option<Vec<f32>>,
    /// Measured cost statistics (paper Table 1, empirically).
    pub stats: GradStats,
}

/// Result of one mini-batch gradient computation: `B` independent IVPs
/// solved through one batched pass (`z0`/`z_final`/`grad_z0` are
/// row-major `[B, N_z]`), with the θ-gradient summed over the batch and
/// [`GradStats`] aggregated per batch.
#[derive(Debug, Clone)]
pub struct BatchGradResult {
    /// Number of samples B.
    pub batch: usize,
    /// Per-sample state dimension N_z.
    pub n_z: usize,
    /// Total loss, summed over the batch.
    pub loss: f64,
    /// Per-sample losses (a single total when the head is not separable,
    /// e.g. the fused device head — see [`BatchLossHead`]).
    pub losses: Vec<f64>,
    /// Terminal states `[B, N_z]`.
    pub z_final: Vec<f32>,
    /// `dL/dθ` summed over the batch (the mini-batch gradient).
    pub grad_theta: Vec<f32>,
    /// `dL/dz₀` rows, `[B, N_z]`.
    pub grad_z0: Vec<f32>,
    /// Reconstructed ẑ(t₀) rows where the method rebuilds the reverse
    /// trajectory (adjoint, MALI) — see [`GradResult::reconstructed_z0`].
    pub reconstructed_z0: Option<Vec<f32>>,
    /// Batch-aggregated cost statistics: counts summed over samples,
    /// `graph_depth` the longest per-sample chain, peak memory from the
    /// shared tracker (Table-1 law with `N_z → B·N_z`).
    pub stats: GradStats,
    /// Per-sample forward statistics (empty on the fused device path,
    /// where the batch shares one controller).
    pub per_sample_fwd: Vec<IntStats>,
}

impl BatchGradResult {
    /// Per-sample losses when the head was separable; `None` on the
    /// device-fused path, where only the batch total ([`Self::loss`],
    /// `losses[0]`) is available.
    pub fn per_sample_losses(&self) -> Option<&[f64]> {
        (self.losses.len() == self.batch).then_some(self.losses.as_slice())
    }
}

/// Result of one multi-observation gradient computation
/// (`L = Σ_k l_k(z(t_k))` over an [`ObsGrid`]).
#[derive(Debug, Clone)]
pub struct ObsGradResult {
    /// Total loss `Σ_k l_k`.
    pub loss: f64,
    /// Per-observation losses `l_k`, in grid order.
    pub obs_losses: Vec<f64>,
    /// Terminal state `z(T)` of the forward solve.
    pub z_final: Vec<f32>,
    /// `dL/dθ` over the dynamics parameters.
    pub grad_theta: Vec<f32>,
    /// `dL/dz₀` over the initial state.
    pub grad_z0: Vec<f32>,
    /// Backward-pass reconstruction ẑ(t₀) — see
    /// [`GradResult::reconstructed_z0`].
    pub reconstructed_z0: Option<Vec<f32>>,
    /// Measured cost statistics (paper Table 1, empirically).
    pub stats: GradStats,
}

/// Result of one mini-batch multi-observation gradient computation:
/// `B` independent IVPs sharing one [`ObsGrid`], θ-gradient summed over
/// the batch, `grad_z0`/`z_final` row-major `[B, N_z]`.
#[derive(Debug, Clone)]
pub struct BatchObsGradResult {
    /// Number of samples B.
    pub batch: usize,
    /// Per-sample state dimension N_z.
    pub n_z: usize,
    /// Total loss over the batch and all observations.
    pub loss: f64,
    /// Per-observation losses summed over the batch, in grid order.
    pub obs_losses: Vec<f64>,
    /// Terminal states `[B, N_z]`.
    pub z_final: Vec<f32>,
    /// `dL/dθ` summed over the batch (the mini-batch gradient).
    pub grad_theta: Vec<f32>,
    /// `dL/dz₀` rows, `[B, N_z]`.
    pub grad_z0: Vec<f32>,
    /// Reconstructed ẑ(t₀) rows where the method rebuilds the reverse
    /// trajectory (adjoint, MALI).
    pub reconstructed_z0: Option<Vec<f32>>,
    /// Batch-aggregated cost statistics (see [`BatchGradResult::stats`]).
    pub stats: GradStats,
    /// Per-sample forward statistics (empty on the fused device path).
    pub per_sample_fwd: Vec<IntStats>,
}

/// One gradient-estimation protocol.
pub trait GradMethod {
    /// Stable identifier used in configs, CLI flags and report tables.
    fn name(&self) -> &'static str;

    /// Compute loss and gradients for the IVP.  `tracker` receives every
    /// buffer the method retains between forward and backward.
    fn grad(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        z0: &[f32],
        loss: &dyn LossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<GradResult>;

    /// Mini-batch gradients for `B` independent IVPs (`z0` is row-major
    /// `[B, N_z]`): per-sample losses and `dL/dz₀` rows, batch-summed
    /// `dL/dθ`, per-sample step control (each row's accepted grid matches
    /// a solo run of that row).
    ///
    /// The default loops rows through [`GradMethod::grad`] — the
    /// single-sample fallback.  The four protocols override it with truly
    /// batched passes (batched tapes/checkpoints/ψ⁻¹ sweeps).  For
    /// device-batched dynamics use [`batch_driver::grad_batched`], which
    /// dispatches to one fused device call instead; calling this directly
    /// on an `HloDynamics` is a contract violation.
    #[allow(clippy::too_many_arguments)]
    fn grad_batch(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        z0: &[f32],
        bspec: &BatchSpec,
        loss: &dyn BatchLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<BatchGradResult> {
        anyhow::ensure!(
            loss.separable(),
            "the single-sample grad_batch fallback evaluates the loss head \
             row by row; this head couples rows (separable() == false) and \
             must go through batch_driver::grad_batched's device-fused path"
        );
        let mut rows = Vec::with_capacity(bspec.batch);
        for b in 0..bspec.batch {
            let row_loss = batch_driver::SummedLoss {
                inner: loss,
                spec: BatchSpec::single(bspec.n_z),
            };
            rows.push(self.grad(
                dynamics,
                solver,
                spec,
                bspec.row(z0, b),
                &row_loss,
                tracker.clone(),
            )?);
        }
        Ok(batch_driver::merge_row_results(rows, bspec, &tracker))
    }

    /// Loss and gradients for a **multi-observation** objective
    /// `L = Σ_k l_k(z(t_k))` over `grid` in one pass — each method keeps
    /// its Table-1 memory/accuracy signature (see the module docs).  The
    /// integration must use the observation-aware loops, so every `t_k`
    /// is hit bitwise and the backward injection points line up.
    #[allow(clippy::too_many_arguments)]
    fn grad_obs(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        grid: &ObsGrid,
        z0: &[f32],
        loss: &dyn ObsLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<ObsGradResult>;

    /// Mini-batch multi-observation gradients: `B` independent IVPs
    /// sharing one `grid`, per-sample step control, batch-summed `dL/dθ`.
    ///
    /// The default loops rows through [`GradMethod::grad_obs`] (requires a
    /// separable head); the four protocols override it with truly batched
    /// passes.  Device-batched dynamics must go through
    /// [`batch_driver::grad_obs_batched`] instead.
    #[allow(clippy::too_many_arguments)]
    fn grad_obs_batch(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        grid: &ObsGrid,
        z0: &[f32],
        bspec: &BatchSpec,
        loss: &dyn BatchObsLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<BatchObsGradResult> {
        anyhow::ensure!(
            loss.separable(),
            "the single-sample grad_obs_batch fallback evaluates the loss \
             head row by row; this head couples rows (separable() == false) \
             and must go through batch_driver::grad_obs_batched's \
             device-fused path"
        );
        let mut rows = Vec::with_capacity(bspec.batch);
        for b in 0..bspec.batch {
            let row_loss = batch_driver::SummedObsLoss {
                inner: loss,
                spec: BatchSpec::single(bspec.n_z),
            };
            rows.push(self.grad_obs(
                dynamics,
                solver,
                spec,
                grid,
                bspec.row(z0, b),
                &row_loss,
                tracker.clone(),
            )?);
        }
        Ok(batch_driver::merge_row_obs_results(rows, grid.len(), bspec, &tracker))
    }
}

/// Method construction by config/CLI name.
///
/// Accepted names: `"mali"`, `"aca"`, `"naive"`, `"adjoint"`, and the
/// adjoint-seminorm variant under either of its two aliases
/// `"adjoint-seminorm"` / `"seminorm"` (both construct the same method,
/// whose [`GradMethod::name`] reports `"adjoint-seminorm"`).  The box is
/// `Send + Sync` so one method can drive pooled batch shards.
pub fn by_name(name: &str) -> Result<Box<dyn GradMethod + Send + Sync>> {
    Ok(match name {
        "mali" => Box::new(mali::Mali),
        "aca" => Box::new(aca::Aca),
        "naive" => Box::new(naive::Naive),
        "adjoint" => Box::new(adjoint::Adjoint::default()),
        "adjoint-seminorm" | "seminorm" => Box::new(adjoint::Adjoint { seminorm: true }),
        "symplectic" => Box::new(symplectic::SymplecticAdjoint),
        other => anyhow::bail!("unknown gradient method '{other}'"),
    })
}

/// The forward-only pass (inference): integrate and apply the loss head.
pub fn forward_loss(
    dynamics: &dyn Dynamics,
    solver: &dyn Solver,
    spec: &IvpSpec,
    z0: &[f32],
    loss: &dyn LossHead,
) -> Result<(f64, Vec<f32>, IntStats)> {
    let s0 = solver.init(dynamics, spec.t0, z0);
    let (sf, stats) = crate::solvers::integrate::integrate(
        solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, &mut (),
    )?;
    let (l, _) = loss.loss_grad(&sf.z);
    Ok((l, sf.z, stats))
}

/// The forward-only multi-observation pass: one observation-aware
/// integration, the loss evaluated at every exact-hit observation state.
/// Returns `(Σ_k l_k, per-observation losses, z(T), stats)` — the
/// finite-difference anchor for [`GradMethod::grad_obs`].
pub fn forward_loss_obs(
    dynamics: &dyn Dynamics,
    solver: &dyn Solver,
    spec: &IvpSpec,
    grid: &ObsGrid,
    z0: &[f32],
    loss: &dyn ObsLossHead,
) -> Result<(f64, Vec<f64>, Vec<f32>, IntStats)> {
    struct Capture(Vec<(usize, f64, Vec<f32>)>);
    impl crate::solvers::integrate::StepObserver for Capture {
        fn on_observation(&mut self, k: usize, t: f64, state: &crate::solvers::State) {
            self.0.push((k, t, state.z.clone()));
        }
    }
    let s0 = solver.init(dynamics, spec.t0, z0);
    let mut cap = Capture(Vec::new());
    let (sf, stats) = crate::solvers::integrate::integrate_obs(
        solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, grid, &mut cap,
    )?;
    let mut obs_losses = vec![0.0f64; grid.len()];
    for (k, t, z) in &cap.0 {
        let (l, _) = loss.loss_grad_at(*k, *t, z);
        obs_losses[*k] = l;
    }
    Ok((obs_losses.iter().sum(), obs_losses, sf.z, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_loss_grad() {
        let (l, g) = SquareLoss.loss_grad(&[1.0, -2.0]);
        assert_eq!(l, 5.0);
        assert_eq!(g, vec![2.0, -4.0]);
    }

    #[test]
    fn factory_covers_methods() {
        for m in ["mali", "aca", "naive", "adjoint", "seminorm", "symplectic"] {
            assert!(by_name(m).is_ok(), "{m}");
        }
        assert!(by_name("bogus").is_err());
    }

    /// Both seminorm aliases round-trip to the `"adjoint-seminorm"` name
    /// (the string configs and report tables use).
    #[test]
    fn by_name_seminorm_aliases_roundtrip() {
        for alias in ["adjoint-seminorm", "seminorm"] {
            let m = by_name(alias).unwrap();
            assert_eq!(m.name(), "adjoint-seminorm", "alias '{alias}'");
            // and the canonical name itself round-trips through the factory
            assert!(by_name(m.name()).is_ok());
        }
        assert_eq!(by_name("adjoint").unwrap().name(), "adjoint");
    }

    /// The blanket `BatchLossHead` impl applies a separable head row-wise.
    #[test]
    fn batch_loss_head_rows() {
        let spec = BatchSpec::new(2, 2);
        let (losses, g) = SquareLoss.loss_grad_batch(&[1.0, -2.0, 3.0, 0.0], &spec);
        assert_eq!(losses, vec![5.0, 9.0]);
        assert_eq!(g, vec![2.0, -4.0, 6.0, 0.0]);
    }

    /// The blanket `BatchObsLossHead` impl applies an observation head
    /// row-wise; the fused adapter couples rows and says so.
    #[test]
    fn obs_loss_heads() {
        let head = ObsSquareLoss {
            weights: vec![2.0],
        };
        let (l, g) = head.loss_grad_at(0, 0.5, &[1.0, -2.0]);
        assert_eq!(l, 10.0);
        assert_eq!(g, vec![4.0, -8.0]);
        // missing weights default to 1
        let (l1, _) = head.loss_grad_at(3, 0.5, &[1.0]);
        assert_eq!(l1, 1.0);

        let spec = BatchSpec::new(2, 2);
        let (ls, gb) = head.loss_grad_at_batch(0, 0.5, &[1.0, -2.0, 3.0, 0.0], &spec);
        assert_eq!(ls, vec![10.0, 18.0]);
        assert_eq!(gb, vec![4.0, -8.0, 12.0, 0.0]);
        assert!(BatchObsLossHead::separable(&head));

        let fused = FusedObsLoss(|_k, _t, z: &[f32]| {
            (z.iter().map(|&x| x as f64).sum(), vec![1.0f32; z.len()])
        });
        assert!(!fused.separable());
        let (ls, gb) = fused.loss_grad_at_batch(0, 0.5, &[1.0, 2.0, 3.0, 4.0], &spec);
        assert_eq!(ls, vec![10.0]);
        assert_eq!(gb.len(), 4);
    }

    /// `forward_loss_obs` reads the exact-hit observation states: on the
    /// linear toy each observation loss has a closed form.
    #[test]
    fn forward_loss_obs_matches_analytic() {
        use crate::solvers::by_name as solver_by_name;
        use crate::solvers::dynamics::LinearToy;
        let toy = LinearToy::new(0.5, 1);
        let solver = solver_by_name("dopri5").unwrap();
        let spec = IvpSpec::adaptive(0.0, 1.0, 1e-8, 1e-10);
        let grid = ObsGrid::new(vec![0.5, 1.0]).unwrap();
        let head = ObsSquareLoss { weights: vec![1.0, 1.0] };
        let (total, per, zf, stats) =
            forward_loss_obs(&toy, &*solver, &spec, &grid, &[1.0], &head).unwrap();
        let want = |t: f64| (0.5f64 * t).exp().powi(2);
        assert!((per[0] - want(0.5)).abs() < 1e-4, "{}", per[0]);
        assert!((per[1] - want(1.0)).abs() < 1e-4, "{}", per[1]);
        assert!((total - per[0] - per[1]).abs() < 1e-12);
        assert!((zf[0] as f64 - 0.5f64.exp()).abs() < 1e-4);
        assert!(stats.n_accepted >= 2);
    }
}
