//! The adjoint method (Chen et al., 2018) — constant memory, but the
//! reverse-time trajectory is *re-solved* as a separate IVP and therefore
//! only approximates the forward trajectory (paper Thm. 2.1): numerical
//! error in ẑ(τ) propagates into `dL/dθ` through Eq. (2).
//!
//! Backward dynamics over the augmented state `y = [z, a, g_θ]`:
//!
//! ```text
//! dz/dt  = f(t, z)
//! da/dt  = −aᵀ ∂f/∂z
//! dg/dt  = −aᵀ ∂f/∂θ
//! ```
//!
//! integrated from `T` down to `t₀` with `a(T) = ∂L/∂z_T`, `g(T) = 0`.
//!
//! The `seminorm` flag enables the adjoint-seminorm trick (Kidger et al.
//! 2020a, the paper's "SemiNorm" baseline): the `g_θ` block is excluded
//! from the adaptive error norm, which loosens step-size control where it
//! does not matter and speeds the backward solve.

use super::{
    BatchGradResult, BatchLossHead, BatchObsGradResult, BatchObsLossHead, GradMethod, GradResult,
    GradStats, IvpSpec, LossHead, ObsGrid, ObsGradResult, ObsLossHead,
};
use crate::solvers::batch::BatchSpec;
use crate::solvers::dynamics::{Dynamics, EvalCounters};
use crate::solvers::integrate::{
    integrate, integrate_batch, integrate_batch_obs, integrate_obs, integrate_ws,
    BatchStepObserver, ErrorNorm, StepMode, StepObserver,
};
use crate::solvers::workspace::SolverWorkspace;
use crate::solvers::{Solver, State};
use crate::tensor::axpy;
use crate::util::mem::{MemTracker, TrackedBuf};
use anyhow::{ensure, Result};
use std::cell::RefCell;
use std::sync::Arc;

#[derive(Default)]
pub struct Adjoint {
    pub seminorm: bool,
}

impl Adjoint {
    /// Error norm for the `[z, a, g_θ]` reverse solve: the seminorm
    /// variant masks the `g_θ` block; otherwise a forward `Semi` mask is
    /// extended to the augmented row layout.
    fn augmented_norm(&self, fwd: &ErrorNorm, d: usize, p: usize) -> ErrorNorm {
        if self.seminorm {
            let mut mask = vec![true; 2 * d + p];
            for m in mask.iter_mut().skip(2 * d) {
                *m = false;
            }
            ErrorNorm::Semi(mask)
        } else {
            match fwd {
                ErrorNorm::Full => ErrorNorm::Full,
                ErrorNorm::Semi(m) => {
                    let mut mask = vec![true; 2 * d + p];
                    mask[..d].copy_from_slice(m);
                    ErrorNorm::Semi(mask)
                }
            }
        }
    }
}

/// Forward-pass observation capture for the solo adjoint: the stored
/// `z(t_k)` rows the loss reads and the reverse solve re-anchors to —
/// `K·N_z` retained bytes, independent of the step count, tracked like
/// any other checkpoint.
struct ObsCapture {
    tracker: Arc<MemTracker>,
    /// `(k, t_k, z(t_k))` in forward (grid) order.
    states: Vec<(usize, f64, TrackedBuf)>,
}

impl StepObserver for ObsCapture {
    fn on_observation(&mut self, k: usize, t: f64, state: &State) {
        self.states
            .push((k, t, TrackedBuf::new(state.z.clone(), self.tracker.clone())));
    }
}

/// Batched observation capture: one flat `[B, N_z]` buffer per
/// observation, rows filled as each sample's controller lands on `t_k`.
struct BatchObsCapture {
    spec: BatchSpec,
    states: Vec<TrackedBuf>,
}

impl BatchObsCapture {
    fn new(tracker: &Arc<MemTracker>, spec: BatchSpec, k: usize) -> Self {
        let states = (0..k)
            .map(|_| TrackedBuf::new(vec![0.0f32; spec.flat_len()], tracker.clone()))
            .collect();
        BatchObsCapture { spec, states }
    }
}

impl BatchStepObserver for BatchObsCapture {
    fn on_observation(&mut self, sample: usize, k: usize, _t: f64, z: &[f32], _v: Option<&[f32]>) {
        self.spec
            .row_mut(&mut self.states[k].data, sample)
            .copy_from_slice(z);
    }
}

/// One augmented-RHS row `[dz, −aᵀ∂f/∂z, −aᵀ∂f/∂θ]` composed from the
/// base dynamics — shared by the solo and batched augmented systems so
/// the composition cannot drift between them.
fn augmented_rhs(base: &dyn Dynamics, d: usize, n_aug: usize, t: f64, y: &[f32]) -> Vec<f32> {
    let (z, rest) = y.split_at(d);
    let (a, _g) = rest.split_at(d);
    let dz = base.f(t, z);
    let (az, ath) = base.f_vjp(t, z, a);
    let mut out = Vec::with_capacity(n_aug);
    out.extend_from_slice(&dz);
    out.extend(az.iter().map(|&x| -x));
    out.extend(ath.iter().map(|&x| -x));
    out
}

/// `[z, a, g_θ]` augmented reverse dynamics composed from the base model's
/// `f` and `f_vjp`.
struct AugmentedAdjoint<'a> {
    base: &'a dyn Dynamics,
    d: usize,
    p: usize,
    counters: EvalCounters,
    empty: Vec<f32>,
    /// θ-cotangent scratch for the allocation-free `f_into` path (the
    /// reverse solve's cotangent jumps previously rebuilt this per eval).
    th_scratch: RefCell<Vec<f32>>,
}

impl<'a> AugmentedAdjoint<'a> {
    fn new(base: &'a dyn Dynamics) -> Self {
        AugmentedAdjoint {
            d: base.dim(),
            p: base.param_dim(),
            base,
            counters: EvalCounters::default(),
            empty: Vec::new(),
            th_scratch: RefCell::new(Vec::new()),
        }
    }
}

impl Dynamics for AugmentedAdjoint<'_> {
    fn dim(&self) -> usize {
        2 * self.d + self.p
    }

    fn param_dim(&self) -> usize {
        0
    }

    fn f(&self, t: f64, y: &[f32]) -> Vec<f32> {
        self.counters.f_evals.add(1);
        augmented_rhs(self.base, self.d, self.dim(), t, y)
    }

    /// Block-wise in-place augmented RHS — value-identical to
    /// [`augmented_rhs`] but writing straight into the solver's stage
    /// buffer, so the reverse augmented IVP runs without per-eval
    /// allocations when the base dynamics has in-place paths.
    fn f_into(&self, t: f64, y: &[f32], out: &mut [f32]) {
        self.counters.f_evals.add(1);
        let d = self.d;
        let (z, rest) = y.split_at(d);
        let (a, _g) = rest.split_at(d);
        let (dz_out, rest_out) = out.split_at_mut(d);
        let (da_out, dg_out) = rest_out.split_at_mut(d);
        self.base.f_into(t, z, dz_out);
        let mut th = self.th_scratch.borrow_mut();
        if th.len() != self.p {
            th.clear();
            th.resize(self.p, 0.0);
        } else {
            th.fill(0.0);
        }
        self.base.f_vjp_into(t, z, a, da_out, &mut th);
        for x in da_out.iter_mut() {
            *x = -*x;
        }
        for (g, &thv) in dg_out.iter_mut().zip(th.iter()) {
            *g = -thv;
        }
    }

    fn f_vjp(&self, _t: f64, _z: &[f32], _a: &[f32]) -> (Vec<f32>, Vec<f32>) {
        unimplemented!(
            "second-order vjp through the adjoint's augmented dynamics is \
             never required (the adjoint method does not backprop through \
             its own reverse solve)"
        )
    }

    fn params(&self) -> &[f32] {
        &self.empty
    }

    fn set_params(&mut self, _theta: &[f32]) {}

    fn counters(&self) -> &EvalCounters {
        &self.counters
    }

    fn depth_nf(&self) -> usize {
        self.base.depth_nf()
    }
}

/// Batched `[z, a, g_θ]` reverse dynamics: each row of width `2d + p` is
/// one sample's augmented state.  `f_batch` gathers the `z` and `a` blocks
/// of all rows and makes one batched `f` + one batched per-row vjp call on
/// the base dynamics — each sample integrates its own `g_θ` block, so the
/// per-row θ-cotangent variant is required.
struct BatchAugmentedAdjoint<'a> {
    base: &'a dyn Dynamics,
    d: usize,
    p: usize,
    counters: EvalCounters,
    empty: Vec<f32>,
}

impl<'a> BatchAugmentedAdjoint<'a> {
    fn new(base: &'a dyn Dynamics, d: usize) -> Self {
        BatchAugmentedAdjoint {
            d,
            p: base.param_dim(),
            base,
            counters: EvalCounters::default(),
            empty: Vec::new(),
        }
    }

    fn n_aug(&self) -> usize {
        2 * self.d + self.p
    }
}

impl Dynamics for BatchAugmentedAdjoint<'_> {
    fn dim(&self) -> usize {
        self.n_aug()
    }

    fn param_dim(&self) -> usize {
        0
    }

    /// Single-row augmented RHS — the same shared composition as the solo
    /// `AugmentedAdjoint::f`, used by per-row fallbacks.
    fn f(&self, t: f64, y: &[f32]) -> Vec<f32> {
        self.counters.f_evals.add(1);
        augmented_rhs(self.base, self.d, self.n_aug(), t, y)
    }

    fn f_batch(&self, ts: &[f64], y: &[f32], spec: &BatchSpec) -> Vec<f32> {
        debug_assert_eq!(spec.n_z, self.n_aug());
        self.counters.f_evals.add(spec.batch as u64);
        let (d, p) = (self.d, self.p);
        let base_spec = BatchSpec::new(spec.batch, d);
        // gather the z and a blocks of every row
        let mut z_rows = Vec::with_capacity(spec.batch * d);
        let mut a_rows = Vec::with_capacity(spec.batch * d);
        for b in 0..spec.batch {
            let row = spec.row(y, b);
            z_rows.extend_from_slice(&row[..d]);
            a_rows.extend_from_slice(&row[d..2 * d]);
        }
        let dz = self.base.f_batch(ts, &z_rows, &base_spec);
        let (az, ath_rows) = self.base.f_vjp_batch_rows(ts, &z_rows, &a_rows, &base_spec);
        let mut out = Vec::with_capacity(spec.flat_len());
        for b in 0..spec.batch {
            out.extend_from_slice(base_spec.row(&dz, b));
            out.extend(base_spec.row(&az, b).iter().map(|&x| -x));
            out.extend(ath_rows[b * p..(b + 1) * p].iter().map(|&x| -x));
        }
        out
    }

    fn f_vjp(&self, _t: f64, _z: &[f32], _a: &[f32]) -> (Vec<f32>, Vec<f32>) {
        unimplemented!(
            "second-order vjp through the adjoint's augmented dynamics is \
             never required (the adjoint method does not backprop through \
             its own reverse solve)"
        )
    }

    fn params(&self) -> &[f32] {
        &self.empty
    }

    fn set_params(&mut self, _theta: &[f32]) {}

    fn counters(&self) -> &EvalCounters {
        &self.counters
    }

    fn depth_nf(&self) -> usize {
        self.base.depth_nf()
    }
}

impl GradMethod for Adjoint {
    fn name(&self) -> &'static str {
        if self.seminorm {
            "adjoint-seminorm"
        } else {
            "adjoint"
        }
    }

    fn grad(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        z0: &[f32],
        loss: &dyn LossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<GradResult> {
        let c = dynamics.counters();
        c.reset();
        let (d, p) = (dynamics.dim(), dynamics.param_dim());

        // ---- forward: discard trajectory, keep z(T) only ----------------
        let s0 = solver.init(dynamics, spec.t0, z0);
        let (s_end, fwd) = integrate(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, &mut (),
        )?;
        let kept = TrackedBuf::new(s_end.z.clone(), tracker.clone());
        let (loss_val, dl_dz) = loss.loss_grad(&kept.data);

        // ---- backward: separate reverse-time IVP -------------------------
        let aug = AugmentedAdjoint::new(dynamics);
        let mut y = Vec::with_capacity(2 * d + p);
        y.extend_from_slice(&kept.data);
        y.extend_from_slice(&dl_dz);
        y.resize(y.len() + p, 0.0);

        // Seminorm: mask the g_θ block out of the error norm.
        let norm = self.augmented_norm(&spec.norm, d, p);
        // Same solver family, reverse direction; the reverse IVP borrows
        // its loop buffers from a workspace (augmented `f_into` writes the
        // stage RHS in place).
        let mut ws = SolverWorkspace::new();
        let ys0 = solver.init(&aug, spec.t1, &y);
        let bwd = integrate_ws(
            solver,
            &aug,
            spec.t1,
            spec.t0,
            &ys0,
            &reverse_mode(&spec.mode),
            &norm,
            &mut (),
            &mut ws,
        )?;
        let y_end = ws.take_output();
        let reconstructed_z0 = y_end.z[..d].to_vec();
        let grad_z0 = y_end.z[d..2 * d].to_vec();
        let grad_theta = y_end.z[2 * d..].to_vec();

        let stats = GradStats {
            bwd_steps: bwd.n_accepted,
            // each augmented eval costs one base f + one base vjp
            f_evals: c.f_evals.get(),
            vjp_evals: c.vjp_evals.get(),
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * bwd.n_accepted.max(1),
            fwd,
        };
        Ok(GradResult {
            loss: loss_val,
            z_final: kept.data.clone(),
            grad_theta,
            grad_z0,
            reconstructed_z0: Some(reconstructed_z0),
            stats,
        })
    }

    /// Batched adjoint: one forward batched solve (trajectory discarded),
    /// then one batched reverse-time solve of the per-row `[z, a, g_θ]`
    /// augmented system under per-sample step control — every row carries
    /// its own `g_θ` block, summed into the mini-batch θ-gradient at the
    /// end.  Memory stays `B·N_z·N_f`, independent of step count.
    #[allow(clippy::too_many_arguments)]
    fn grad_batch(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        z0: &[f32],
        bspec: &BatchSpec,
        loss: &dyn BatchLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<BatchGradResult> {
        let c = dynamics.counters();
        let f0 = c.f_evals.get();
        let v0 = c.vjp_evals.get();
        let (d, p) = (bspec.n_z, dynamics.param_dim());

        // ---- forward: discard trajectory, keep z(T) rows only ----------
        let s0 = solver.init_batch(dynamics, spec.t0, z0, bspec);
        let (s_end, fwd) = integrate_batch(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, &mut (),
        )?;
        let kept = TrackedBuf::new(s_end.z.data.clone(), tracker.clone());
        let (losses, dl_dz) = loss.loss_grad_batch(&kept.data, bspec);

        // ---- backward: batched reverse-time augmented IVP --------------
        let aug = BatchAugmentedAdjoint::new(dynamics, d);
        let n_aug = 2 * d + p;
        let aug_spec = BatchSpec::new(bspec.batch, n_aug);
        let mut y = Vec::with_capacity(aug_spec.flat_len());
        for b in 0..bspec.batch {
            y.extend_from_slice(bspec.row(&kept.data, b));
            y.extend_from_slice(bspec.row(&dl_dz, b));
            y.resize(y.len() + p, 0.0);
        }

        // Seminorm: mask the g_θ block out of each row's error norm.
        let norm = self.augmented_norm(&spec.norm, d, p);
        let ys0 = solver.init_batch(&aug, spec.t1, &y, &aug_spec);
        let (y_end, bwd) = integrate_batch(
            solver,
            &aug,
            spec.t1,
            spec.t0,
            ys0,
            &reverse_mode(&spec.mode),
            &norm,
            &mut (),
        )?;

        // unpack rows: ẑ(t₀) | dL/dz₀ | g_θ (summed over the batch)
        let mut reconstructed = Vec::with_capacity(bspec.flat_len());
        let mut grad_z0 = Vec::with_capacity(bspec.flat_len());
        let mut grad_theta = vec![0.0f32; p];
        for b in 0..bspec.batch {
            let row = aug_spec.row(&y_end.z.data, b);
            reconstructed.extend_from_slice(&row[..d]);
            grad_z0.extend_from_slice(&row[d..2 * d]);
            axpy(1.0, &row[2 * d..], &mut grad_theta);
        }

        let stats = GradStats {
            bwd_steps: bwd.n_accepted_total(),
            f_evals: c.f_evals.get() - f0,
            vjp_evals: c.vjp_evals.get() - v0,
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * bwd.n_accepted_max().max(1),
            fwd: fwd.aggregate(),
        };
        Ok(BatchGradResult {
            batch: bspec.batch,
            n_z: bspec.n_z,
            loss: losses.iter().sum(),
            losses,
            z_final: kept.data.clone(),
            grad_theta,
            grad_z0,
            reconstructed_z0: Some(reconstructed),
            stats,
            per_sample_fwd: fwd.per_sample,
        })
    }

    /// Multi-observation adjoint (Chen et al. 2018, App. B): one reverse
    /// augmented IVP from `t1` to `t0` with **jump discontinuities** at
    /// every observation — the cotangent `∂l_k/∂z` is added to the
    /// `a`-block when the solve passes `t_k`, and the `ẑ` block is
    /// re-anchored to the stored forward state there (the torchdiffeq
    /// convention, bounding reverse-trajectory drift to one segment).
    /// Retained memory is the end state plus the K observation states —
    /// still independent of the step count.
    #[allow(clippy::too_many_arguments)]
    fn grad_obs(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        grid: &ObsGrid,
        z0: &[f32],
        loss: &dyn ObsLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<ObsGradResult> {
        ensure!(
            !grid.is_empty(),
            "empty observation grid; use grad() for a terminal loss"
        );
        let c = dynamics.counters();
        c.reset();
        let (d, p) = (dynamics.dim(), dynamics.param_dim());

        // ---- forward: keep the observation states (the loss reads them)
        let s0 = solver.init(dynamics, spec.t0, z0);
        let mut cap = ObsCapture {
            tracker: tracker.clone(),
            states: Vec::new(),
        };
        let (s_end, fwd) = integrate_obs(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, grid, &mut cap,
        )?;
        let kept = TrackedBuf::new(s_end.z.clone(), tracker.clone());

        // ---- backward: reverse augmented IVP with cotangent jumps ------
        // One workspace is shared across every inter-observation segment,
        // so the per-segment reverse solves (and the jumps between them)
        // reuse the same stage/state buffers instead of reallocating the
        // augmented vectors they immediately overwrite.
        let aug = AugmentedAdjoint::new(dynamics);
        let norm = self.augmented_norm(&spec.norm, d, p);
        let mut ws = SolverWorkspace::new();
        let mut y = Vec::with_capacity(2 * d + p);
        y.extend_from_slice(&kept.data);
        y.resize(2 * d + p, 0.0);
        let mut t_cur = spec.t1;
        let mut bwd_steps = 0usize;
        let mut obs_losses = vec![0.0f64; grid.len()];
        for (k, t_k, zbuf) in cap.states.iter().rev() {
            if *t_k != t_cur {
                let ys0 = solver.init(&aug, t_cur, &y);
                let seg = integrate_ws(
                    solver,
                    &aug,
                    t_cur,
                    *t_k,
                    &ys0,
                    &reverse_mode(&spec.mode),
                    &norm,
                    &mut (),
                    &mut ws,
                )?;
                y.copy_from_slice(&ws.output().z);
                bwd_steps += seg.n_accepted;
                t_cur = *t_k;
            }
            // re-anchor ẑ to the stored forward state, then the jump
            y[..d].copy_from_slice(&zbuf.data);
            let (l, g) = loss.loss_grad_at(*k, *t_k, &zbuf.data);
            obs_losses[*k] = l;
            axpy(1.0, &g, &mut y[d..2 * d]);
        }
        // final leg down to t0 (observations are strictly inside (t0, t1])
        let ys0 = solver.init(&aug, t_cur, &y);
        let seg = integrate_ws(
            solver,
            &aug,
            t_cur,
            spec.t0,
            &ys0,
            &reverse_mode(&spec.mode),
            &norm,
            &mut (),
            &mut ws,
        )?;
        let y_end = ws.take_output();
        bwd_steps += seg.n_accepted;
        let reconstructed_z0 = y_end.z[..d].to_vec();
        let grad_z0 = y_end.z[d..2 * d].to_vec();
        let grad_theta = y_end.z[2 * d..].to_vec();

        let stats = GradStats {
            bwd_steps,
            f_evals: c.f_evals.get(),
            vjp_evals: c.vjp_evals.get(),
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * bwd_steps.max(1),
            fwd,
        };
        Ok(ObsGradResult {
            loss: obs_losses.iter().sum(),
            obs_losses,
            z_final: kept.data.clone(),
            grad_theta,
            grad_z0,
            reconstructed_z0: Some(reconstructed_z0),
            stats,
        })
    }

    /// Batched multi-observation adjoint: one batched reverse augmented
    /// IVP per inter-observation segment under per-sample step control,
    /// with batch-synchronous jumps (all rows share the grid, so each
    /// observation's cotangent is one full-batch head call — fused
    /// non-separable heads work on this path).
    #[allow(clippy::too_many_arguments)]
    fn grad_obs_batch(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        grid: &ObsGrid,
        z0: &[f32],
        bspec: &BatchSpec,
        loss: &dyn BatchObsLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<BatchObsGradResult> {
        ensure!(
            !grid.is_empty(),
            "empty observation grid; use grad_batch() for a terminal loss"
        );
        let c = dynamics.counters();
        let f0 = c.f_evals.get();
        let v0 = c.vjp_evals.get();
        let (d, p) = (bspec.n_z, dynamics.param_dim());

        // ---- forward: per-observation [B, N_z] state capture -----------
        let s0 = solver.init_batch(dynamics, spec.t0, z0, bspec);
        let mut cap = BatchObsCapture::new(&tracker, *bspec, grid.len());
        let (s_end, fwd) = integrate_batch_obs(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, grid, &mut cap,
        )?;
        let kept = TrackedBuf::new(s_end.z.data.clone(), tracker.clone());

        // ---- backward: segment-wise batched reverse augmented IVP ------
        let aug = BatchAugmentedAdjoint::new(dynamics, d);
        let n_aug = 2 * d + p;
        let aug_spec = BatchSpec::new(bspec.batch, n_aug);
        let norm = self.augmented_norm(&spec.norm, d, p);
        let mut y = Vec::with_capacity(aug_spec.flat_len());
        for b in 0..bspec.batch {
            y.extend_from_slice(bspec.row(&kept.data, b));
            y.resize((b + 1) * n_aug, 0.0);
        }
        let mut t_cur = spec.t1;
        let mut bwd_acc = vec![0usize; bspec.batch];
        let mut obs_losses = vec![0.0f64; grid.len()];
        for k in (0..grid.len()).rev() {
            let t_k = grid.time(k);
            if t_k != t_cur {
                let ys0 = solver.init_batch(&aug, t_cur, &y, &aug_spec);
                let (y_end, seg) = integrate_batch(
                    solver,
                    &aug,
                    t_cur,
                    t_k,
                    ys0,
                    &reverse_mode(&spec.mode),
                    &norm,
                    &mut (),
                )?;
                y = y_end.z.data;
                for (b, s) in seg.per_sample.iter().enumerate() {
                    bwd_acc[b] += s.n_accepted;
                }
                t_cur = t_k;
            }
            // re-anchor ẑ rows to the stored forward states and apply the
            // batch cotangent jump
            let (ls, g) = loss.loss_grad_at_batch(k, t_k, &cap.states[k].data, bspec);
            obs_losses[k] = ls.iter().sum();
            for b in 0..bspec.batch {
                let row = &mut y[b * n_aug..(b + 1) * n_aug];
                row[..d].copy_from_slice(bspec.row(&cap.states[k].data, b));
                axpy(1.0, bspec.row(&g, b), &mut row[d..2 * d]);
            }
        }
        // final leg down to t0
        let ys0 = solver.init_batch(&aug, t_cur, &y, &aug_spec);
        let (y_end, seg) = integrate_batch(
            solver,
            &aug,
            t_cur,
            spec.t0,
            ys0,
            &reverse_mode(&spec.mode),
            &norm,
            &mut (),
        )?;
        for (b, s) in seg.per_sample.iter().enumerate() {
            bwd_acc[b] += s.n_accepted;
        }

        // unpack rows: ẑ(t₀) | dL/dz₀ | g_θ (summed over the batch)
        let mut reconstructed = Vec::with_capacity(bspec.flat_len());
        let mut grad_z0 = Vec::with_capacity(bspec.flat_len());
        let mut grad_theta = vec![0.0f32; p];
        for b in 0..bspec.batch {
            let row = aug_spec.row(&y_end.z.data, b);
            reconstructed.extend_from_slice(&row[..d]);
            grad_z0.extend_from_slice(&row[d..2 * d]);
            axpy(1.0, &row[2 * d..], &mut grad_theta);
        }

        let stats = GradStats {
            bwd_steps: bwd_acc.iter().sum(),
            f_evals: c.f_evals.get() - f0,
            vjp_evals: c.vjp_evals.get() - v0,
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * bwd_acc.iter().copied().max().unwrap_or(0).max(1),
            fwd: fwd.aggregate(),
        };
        Ok(BatchObsGradResult {
            batch: bspec.batch,
            n_z: bspec.n_z,
            loss: obs_losses.iter().sum(),
            obs_losses,
            z_final: kept.data.clone(),
            grad_theta,
            grad_z0,
            reconstructed_z0: Some(reconstructed),
            stats,
            per_sample_fwd: fwd.per_sample,
        })
    }
}

/// The reverse solve reuses the forward step policy (fixed h keeps its
/// magnitude; adaptive keeps tolerances — direction is handled by the
/// integrate loop).
fn reverse_mode(mode: &StepMode) -> StepMode {
    mode.clone()
}
