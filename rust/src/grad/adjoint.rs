//! The adjoint method (Chen et al., 2018) — constant memory, but the
//! reverse-time trajectory is *re-solved* as a separate IVP and therefore
//! only approximates the forward trajectory (paper Thm. 2.1): numerical
//! error in ẑ(τ) propagates into `dL/dθ` through Eq. (2).
//!
//! Backward dynamics over the augmented state `y = [z, a, g_θ]`:
//!
//! ```text
//! dz/dt  = f(t, z)
//! da/dt  = −aᵀ ∂f/∂z
//! dg/dt  = −aᵀ ∂f/∂θ
//! ```
//!
//! integrated from `T` down to `t₀` with `a(T) = ∂L/∂z_T`, `g(T) = 0`.
//!
//! The `seminorm` flag enables the adjoint-seminorm trick (Kidger et al.
//! 2020a, the paper's "SemiNorm" baseline): the `g_θ` block is excluded
//! from the adaptive error norm, which loosens step-size control where it
//! does not matter and speeds the backward solve.

use super::{GradMethod, GradResult, GradStats, IvpSpec, LossHead};
use crate::solvers::dynamics::{Dynamics, EvalCounters};
use crate::solvers::integrate::{integrate, ErrorNorm, StepMode};
use crate::solvers::Solver;
use crate::util::mem::{MemTracker, TrackedBuf};
use anyhow::Result;
use std::sync::Arc;

#[derive(Default)]
pub struct Adjoint {
    pub seminorm: bool,
}

/// `[z, a, g_θ]` augmented reverse dynamics composed from the base model's
/// `f` and `f_vjp`.
struct AugmentedAdjoint<'a> {
    base: &'a dyn Dynamics,
    d: usize,
    p: usize,
    counters: EvalCounters,
    empty: Vec<f32>,
}

impl<'a> AugmentedAdjoint<'a> {
    fn new(base: &'a dyn Dynamics) -> Self {
        AugmentedAdjoint {
            d: base.dim(),
            p: base.param_dim(),
            base,
            counters: EvalCounters::default(),
            empty: Vec::new(),
        }
    }
}

impl Dynamics for AugmentedAdjoint<'_> {
    fn dim(&self) -> usize {
        2 * self.d + self.p
    }

    fn param_dim(&self) -> usize {
        0
    }

    fn f(&self, t: f64, y: &[f32]) -> Vec<f32> {
        self.counters.f_evals.set(self.counters.f_evals.get() + 1);
        let (z, rest) = y.split_at(self.d);
        let (a, _g) = rest.split_at(self.d);
        let dz = self.base.f(t, z);
        let (az, ath) = self.base.f_vjp(t, z, a);
        let mut out = Vec::with_capacity(self.dim());
        out.extend_from_slice(&dz);
        out.extend(az.iter().map(|&x| -x));
        out.extend(ath.iter().map(|&x| -x));
        out
    }

    fn f_vjp(&self, _t: f64, _z: &[f32], _a: &[f32]) -> (Vec<f32>, Vec<f32>) {
        unimplemented!(
            "second-order vjp through the adjoint's augmented dynamics is \
             never required (the adjoint method does not backprop through \
             its own reverse solve)"
        )
    }

    fn params(&self) -> &[f32] {
        &self.empty
    }

    fn set_params(&mut self, _theta: &[f32]) {}

    fn counters(&self) -> &EvalCounters {
        &self.counters
    }

    fn depth_nf(&self) -> usize {
        self.base.depth_nf()
    }
}

impl GradMethod for Adjoint {
    fn name(&self) -> &'static str {
        if self.seminorm {
            "adjoint-seminorm"
        } else {
            "adjoint"
        }
    }

    fn grad(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        z0: &[f32],
        loss: &dyn LossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<GradResult> {
        let c = dynamics.counters();
        c.reset();
        let (d, p) = (dynamics.dim(), dynamics.param_dim());

        // ---- forward: discard trajectory, keep z(T) only ----------------
        let s0 = solver.init(dynamics, spec.t0, z0);
        let (s_end, fwd) = integrate(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, &mut (),
        )?;
        let kept = TrackedBuf::new(s_end.z.clone(), tracker.clone());
        let (loss_val, dl_dz) = loss.loss_grad(&kept.data);

        // ---- backward: separate reverse-time IVP -------------------------
        let aug = AugmentedAdjoint::new(dynamics);
        let mut y = Vec::with_capacity(2 * d + p);
        y.extend_from_slice(&kept.data);
        y.extend_from_slice(&dl_dz);
        y.extend(std::iter::repeat(0.0f32).take(p));

        // Seminorm: mask the g_θ block out of the error norm.
        let norm = if self.seminorm {
            let mut mask = vec![true; 2 * d + p];
            for m in mask.iter_mut().skip(2 * d) {
                *m = false;
            }
            ErrorNorm::Semi(mask)
        } else {
            match &spec.norm {
                ErrorNorm::Full => ErrorNorm::Full,
                ErrorNorm::Semi(m) => {
                    // extend a forward-state mask to the augmented layout
                    let mut mask = vec![true; 2 * d + p];
                    mask[..d].copy_from_slice(m);
                    ErrorNorm::Semi(mask)
                }
            }
        };
        // Same solver family, reverse direction.
        let ys0 = solver.init(&aug, spec.t1, &y);
        let (y_end, bwd) = integrate(
            solver, &aug, spec.t1, spec.t0, ys0, &reverse_mode(&spec.mode), &norm, &mut (),
        )?;
        let reconstructed_z0 = y_end.z[..d].to_vec();
        let grad_z0 = y_end.z[d..2 * d].to_vec();
        let grad_theta = y_end.z[2 * d..].to_vec();

        let stats = GradStats {
            bwd_steps: bwd.n_accepted,
            // each augmented eval costs one base f + one base vjp
            f_evals: c.f_evals.get(),
            vjp_evals: c.vjp_evals.get(),
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * bwd.n_accepted.max(1),
            fwd,
        };
        Ok(GradResult {
            loss: loss_val,
            z_final: kept.data.clone(),
            grad_theta,
            grad_z0,
            reconstructed_z0: Some(reconstructed_z0),
            stats,
        })
    }
}

/// The reverse solve reuses the forward step policy (fixed h keeps its
/// magnitude; adaptive keeps tolerances — direction is handled by the
/// integrate loop).
fn reverse_mode(mode: &StepMode) -> StepMode {
    mode.clone()
}
