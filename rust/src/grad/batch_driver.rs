//! Mini-batch gradient dispatch: one entry point that routes a `[B, N_z]`
//! batch to the right execution strategy.
//!
//! Dispatch rule (DESIGN.md §3):
//!
//! * **device-fused** — when the dynamics is a device-compiled batched
//!   graph (`HloDynamics`, [`Dynamics::is_device_batched`]), the batch
//!   dimension is baked into the executable, so the driver keeps **one
//!   fused device call** per evaluation: the flat `[B·N_z]` buffer runs
//!   through the single-trajectory [`GradMethod::grad`] under one shared
//!   step controller, exactly as the AOT graphs were lowered.
//! * **native-shard** — host-only dynamics (`LinearToy`, `MlpDynamics`, …)
//!   have no fixed batch, so [`grad_batched_pooled`] shards the rows into
//!   contiguous sub-batches across `util::pool` workers, each running the
//!   truly batched [`GradMethod::grad_batch`] (vectorized rows, per-sample
//!   adaptive control with an active mask).
//!
//! Per-sample results are bit-compatible with solo runs in both serial
//! paths; see `tests/batch_equivalence.rs`.
//!
//! The multi-observation entry points [`grad_obs_batched`] /
//! [`grad_obs_batched_pooled`] apply the same dispatch rule to
//! `L = Σ_k l_k(z(t_k))` objectives over an [`ObsGrid`] — there is no
//! endpoint-only special case left anywhere in this driver: the plain
//! `grad_batched` path is simply the empty-grid degenerate of the
//! observation-aware stack.
//!
//! This driver covers *training* traffic (gradients over mini-batches
//! the caller already assembled).  The online *inference* mirror — many
//! independent single-trajectory requests dynamically coalesced into
//! `[B, N_z]` batches and integrated forward through the same
//! batch-first fast path — lives in [`crate::serve`] (DESIGN.md §10).

use super::{
    BatchGradResult, BatchLossHead, BatchObsGradResult, BatchObsLossHead, GradMethod, GradResult,
    GradStats, IvpSpec, LossHead, ObsGrid, ObsGradResult, ObsLossHead,
};
use crate::solvers::batch::BatchSpec;
use crate::solvers::dynamics::{Dynamics, ScopedDynamics};
use crate::solvers::Solver;
use crate::util::mem::MemTracker;
use crate::util::pool;
use anyhow::{ensure, Result};
use std::sync::Arc;

/// Adapter: view a [`BatchLossHead`] evaluated at a fixed spec as a
/// scalar-total [`LossHead`].  With a `[1, n_z]` spec this is the per-row
/// head the single-sample fallback of [`GradMethod::grad_batch`] feeds to
/// [`GradMethod::grad`]; with the full `[B, n_z]` spec it is the
/// device-fused head (the whole flat buffer as one "trajectory").
pub struct SummedLoss<'a> {
    pub inner: &'a dyn BatchLossHead,
    pub spec: BatchSpec,
}

impl LossHead for SummedLoss<'_> {
    fn loss_grad(&self, z_t: &[f32]) -> (f64, Vec<f32>) {
        let (losses, grad) = self.inner.loss_grad_batch(z_t, &self.spec);
        (losses.iter().sum(), grad)
    }
}

/// Observation analogue of [`SummedLoss`]: a [`BatchObsLossHead`] at a
/// fixed spec viewed as a scalar-total [`ObsLossHead`] — `[1, n_z]` for
/// the single-sample fallback, the full `[B, n_z]` for the device-fused
/// path (the whole flat buffer as one "trajectory").
pub struct SummedObsLoss<'a> {
    pub inner: &'a dyn BatchObsLossHead,
    pub spec: BatchSpec,
}

impl ObsLossHead for SummedObsLoss<'_> {
    fn loss_grad_at(&self, k: usize, t: f64, z: &[f32]) -> (f64, Vec<f32>) {
        let (losses, grad) = self.inner.loss_grad_at_batch(k, t, z, &self.spec);
        (losses.iter().sum(), grad)
    }
}

/// Merge per-row [`GradResult`]s (the single-sample fallback) into one
/// [`BatchGradResult`].
pub fn merge_row_results(
    rows: Vec<GradResult>,
    bspec: &BatchSpec,
    tracker: &Arc<MemTracker>,
) -> BatchGradResult {
    debug_assert_eq!(rows.len(), bspec.batch);
    let p = rows.first().map(|r| r.grad_theta.len()).unwrap_or(0);
    let mut out = BatchGradResult {
        batch: bspec.batch,
        n_z: bspec.n_z,
        loss: 0.0,
        losses: Vec::with_capacity(bspec.batch),
        z_final: Vec::with_capacity(bspec.flat_len()),
        grad_theta: vec![0.0f32; p],
        grad_z0: Vec::with_capacity(bspec.flat_len()),
        reconstructed_z0: rows.iter().all(|r| r.reconstructed_z0.is_some()).then(Vec::new),
        stats: GradStats::default(),
        per_sample_fwd: Vec::with_capacity(bspec.batch),
    };
    for r in rows {
        out.loss += r.loss;
        out.losses.push(r.loss);
        out.z_final.extend_from_slice(&r.z_final);
        crate::tensor::axpy(1.0, &r.grad_theta, &mut out.grad_theta);
        out.grad_z0.extend_from_slice(&r.grad_z0);
        if let (Some(acc), Some(rec)) = (&mut out.reconstructed_z0, &r.reconstructed_z0) {
            acc.extend_from_slice(rec);
        }
        out.stats.bwd_steps += r.stats.bwd_steps;
        out.stats.f_evals += r.stats.f_evals;
        out.stats.vjp_evals += r.stats.vjp_evals;
        out.stats.graph_depth = out.stats.graph_depth.max(r.stats.graph_depth);
        out.stats.fwd.n_accepted += r.stats.fwd.n_accepted;
        out.stats.fwd.n_trials += r.stats.fwd.n_trials;
        out.stats.fwd.f_evals += r.stats.fwd.f_evals;
        out.per_sample_fwd.push(r.stats.fwd);
    }
    out.stats.peak_mem_bytes = tracker.peak_bytes();
    out
}

/// Merge per-row [`ObsGradResult`]s (the single-sample fallback) into one
/// [`BatchObsGradResult`]; `k_obs` is the grid length (per-observation
/// losses sum across rows).
pub fn merge_row_obs_results(
    rows: Vec<ObsGradResult>,
    k_obs: usize,
    bspec: &BatchSpec,
    tracker: &Arc<MemTracker>,
) -> BatchObsGradResult {
    debug_assert_eq!(rows.len(), bspec.batch);
    let p = rows.first().map(|r| r.grad_theta.len()).unwrap_or(0);
    let mut out = BatchObsGradResult {
        batch: bspec.batch,
        n_z: bspec.n_z,
        loss: 0.0,
        obs_losses: vec![0.0f64; k_obs],
        z_final: Vec::with_capacity(bspec.flat_len()),
        grad_theta: vec![0.0f32; p],
        grad_z0: Vec::with_capacity(bspec.flat_len()),
        reconstructed_z0: rows.iter().all(|r| r.reconstructed_z0.is_some()).then(Vec::new),
        stats: GradStats::default(),
        per_sample_fwd: Vec::with_capacity(bspec.batch),
    };
    for r in rows {
        out.loss += r.loss;
        for (acc, l) in out.obs_losses.iter_mut().zip(&r.obs_losses) {
            *acc += l;
        }
        out.z_final.extend_from_slice(&r.z_final);
        crate::tensor::axpy(1.0, &r.grad_theta, &mut out.grad_theta);
        out.grad_z0.extend_from_slice(&r.grad_z0);
        if let (Some(acc), Some(rec)) = (&mut out.reconstructed_z0, &r.reconstructed_z0) {
            acc.extend_from_slice(rec);
        }
        out.stats.bwd_steps += r.stats.bwd_steps;
        out.stats.f_evals += r.stats.f_evals;
        out.stats.vjp_evals += r.stats.vjp_evals;
        out.stats.graph_depth = out.stats.graph_depth.max(r.stats.graph_depth);
        out.stats.fwd.n_accepted += r.stats.fwd.n_accepted;
        out.stats.fwd.n_trials += r.stats.fwd.n_trials;
        out.stats.fwd.f_evals += r.stats.fwd.f_evals;
        out.per_sample_fwd.push(r.stats.fwd);
    }
    out.stats.peak_mem_bytes = tracker.peak_bytes();
    out
}

/// Wrap a flat single-trajectory result (the device-fused path) into the
/// batch container.  Per-sample losses/stats are not separable there: the
/// loss vector carries one total and `per_sample_fwd` is empty.
fn from_fused(res: GradResult, bspec: &BatchSpec) -> BatchGradResult {
    BatchGradResult {
        batch: bspec.batch,
        n_z: bspec.n_z,
        loss: res.loss,
        losses: vec![res.loss],
        z_final: res.z_final,
        grad_theta: res.grad_theta,
        grad_z0: res.grad_z0,
        reconstructed_z0: res.reconstructed_z0,
        stats: res.stats,
        per_sample_fwd: Vec::new(),
    }
}

/// Batched gradients with the device-fused vs native dispatch applied.
///
/// Serial on the host side: native dynamics run one (vectorized) batched
/// pass on the caller thread — per-sample results and eval counts are
/// exact.  Use [`grad_batched_pooled`] to additionally shard native
/// batches across threads.
#[allow(clippy::too_many_arguments)]
pub fn grad_batched(
    method: &dyn GradMethod,
    dynamics: &dyn Dynamics,
    solver: &dyn Solver,
    spec: &IvpSpec,
    z0: &[f32],
    bspec: &BatchSpec,
    loss: &dyn BatchLossHead,
    tracker: Arc<MemTracker>,
) -> Result<BatchGradResult> {
    ensure!(
        z0.len() == bspec.flat_len(),
        "z0 has {} elements, want [{}, {}] = {}",
        z0.len(),
        bspec.batch,
        bspec.n_z,
        bspec.flat_len()
    );
    if dynamics.is_device_batched() {
        ensure!(
            dynamics.dim() == bspec.flat_len(),
            "device-batched dynamics spans {} states but the batch is [{}, {}]",
            dynamics.dim(),
            bspec.batch,
            bspec.n_z
        );
        let fused = SummedLoss { inner: loss, spec: *bspec };
        let res = method.grad(dynamics, solver, spec, z0, &fused, tracker)?;
        Ok(from_fused(res, bspec))
    } else {
        method.grad_batch(dynamics, solver, spec, z0, bspec, loss, tracker)
    }
}

/// Like [`grad_batched`], but native dynamics are sharded into contiguous
/// row blocks across `util::pool` workers (`MALI_THREADS` controls the
/// count) — the training-throughput path for host-only dynamics.
///
/// Requires a separable (per-row) loss head.  Aggregate `f`/vjp counts
/// are measured on a call-local [`ScopedDynamics`] window around the
/// whole pooled pass — exact even when other threads share `dynamics` —
/// but the per-shard split of a pass is not separable, so
/// `stats.fwd.f_evals` is folded into the global `stats.f_evals` rather
/// than split per phase.
#[allow(clippy::too_many_arguments)]
pub fn grad_batched_pooled(
    method: &(dyn GradMethod + Sync),
    dynamics: &(dyn Dynamics + Sync),
    solver: &(dyn Solver + Sync),
    spec: &IvpSpec,
    z0: &[f32],
    bspec: &BatchSpec,
    loss: &(dyn BatchLossHead + Sync),
    tracker: Arc<MemTracker>,
) -> Result<BatchGradResult> {
    let workers = pool::num_threads().min(bspec.batch);
    if dynamics.is_device_batched() || workers <= 1 {
        return grad_batched(method, dynamics, solver, spec, z0, bspec, loss, tracker);
    }
    ensure!(
        loss.separable(),
        "pooled batching requires a separable (per-row) loss head; this head \
         couples rows and can only run serially or device-fused"
    );
    ensure!(
        z0.len() == bspec.flat_len(),
        "z0 has {} elements, want [{}, {}]",
        z0.len(),
        bspec.batch,
        bspec.n_z
    );
    // same balanced contiguous split as the serve layer's intra-batch shards
    let shards: Vec<(usize, usize)> = pool::shard_ranges(bspec.batch, workers)
        .filter(|(s, e)| e > s)
        .collect();
    // scoped counter window: this pass's evaluations are counted on a
    // call-local scope, so a concurrent serve worker (or a second
    // fine-tune loop) sharing `dynamics` never bleeds into these stats —
    // the inner counters still accrue for registry-wide accounting
    let scoped = ScopedDynamics::new(dynamics);
    let dynamics: &(dyn Dynamics + Sync) = &scoped;
    let results: Vec<Result<BatchGradResult>> = pool::par_map(&shards, |&(s, e)| {
        let sub = BatchSpec::new(e - s, bspec.n_z);
        method.grad_batch(
            dynamics,
            solver,
            spec,
            &z0[s * bspec.n_z..e * bspec.n_z],
            &sub,
            loss,
            tracker.clone(),
        )
    });
    let mut parts = Vec::with_capacity(results.len());
    for r in results {
        parts.push(r?);
    }

    // concatenate shard rows in order; θ and counts sum across shards
    let mut out = parts.remove(0);
    for part in parts {
        out.loss += part.loss;
        out.losses.extend(part.losses);
        out.z_final.extend(part.z_final);
        crate::tensor::axpy(1.0, &part.grad_theta, &mut out.grad_theta);
        out.grad_z0.extend(part.grad_z0);
        match (&mut out.reconstructed_z0, part.reconstructed_z0) {
            (Some(acc), Some(rec)) => acc.extend(rec),
            (opt, _) => *opt = None,
        }
        out.stats.bwd_steps += part.stats.bwd_steps;
        out.stats.graph_depth = out.stats.graph_depth.max(part.stats.graph_depth);
        out.stats.fwd.n_accepted += part.stats.fwd.n_accepted;
        out.stats.fwd.n_trials += part.stats.fwd.n_trials;
        out.per_sample_fwd.extend(part.per_sample_fwd);
    }
    out.batch = bspec.batch;
    // exact totals from the scoped counters (shard-local deltas
    // interleave under concurrency; the scope sums them atomically)
    out.stats.f_evals = scoped.counters().f_evals.get();
    out.stats.vjp_evals = scoped.counters().vjp_evals.get();
    out.stats.fwd.f_evals = 0;
    out.stats.peak_mem_bytes = tracker.peak_bytes();
    Ok(out)
}

/// Wrap a flat single-trajectory observation result (the device-fused
/// path) into the batch container; the per-observation losses are already
/// batch totals (the fused head sums rows).
fn from_fused_obs(res: ObsGradResult, bspec: &BatchSpec) -> BatchObsGradResult {
    BatchObsGradResult {
        batch: bspec.batch,
        n_z: bspec.n_z,
        loss: res.loss,
        obs_losses: res.obs_losses,
        z_final: res.z_final,
        grad_theta: res.grad_theta,
        grad_z0: res.grad_z0,
        reconstructed_z0: res.reconstructed_z0,
        stats: res.stats,
        per_sample_fwd: Vec::new(),
    }
}

/// Multi-observation batched gradients with the device-fused vs native
/// dispatch of [`grad_batched`] applied: device-compiled dynamics run the
/// flat buffer through the single-trajectory [`GradMethod::grad_obs`]
/// under one shared controller (one fused head call per observation);
/// native dynamics run the truly batched [`GradMethod::grad_obs_batch`].
#[allow(clippy::too_many_arguments)]
pub fn grad_obs_batched(
    method: &dyn GradMethod,
    dynamics: &dyn Dynamics,
    solver: &dyn Solver,
    spec: &IvpSpec,
    grid: &ObsGrid,
    z0: &[f32],
    bspec: &BatchSpec,
    loss: &dyn BatchObsLossHead,
    tracker: Arc<MemTracker>,
) -> Result<BatchObsGradResult> {
    ensure!(
        z0.len() == bspec.flat_len(),
        "z0 has {} elements, want [{}, {}] = {}",
        z0.len(),
        bspec.batch,
        bspec.n_z,
        bspec.flat_len()
    );
    if dynamics.is_device_batched() {
        ensure!(
            dynamics.dim() == bspec.flat_len(),
            "device-batched dynamics spans {} states but the batch is [{}, {}]",
            dynamics.dim(),
            bspec.batch,
            bspec.n_z
        );
        let fused = SummedObsLoss { inner: loss, spec: *bspec };
        let res = method.grad_obs(dynamics, solver, spec, grid, z0, &fused, tracker)?;
        Ok(from_fused_obs(res, bspec))
    } else {
        method.grad_obs_batch(dynamics, solver, spec, grid, z0, bspec, loss, tracker)
    }
}

/// Like [`grad_obs_batched`], but native dynamics are sharded into
/// contiguous row blocks across `util::pool` workers — requires a
/// separable (per-row) observation head; see [`grad_batched_pooled`] for
/// the counting conventions.
#[allow(clippy::too_many_arguments)]
pub fn grad_obs_batched_pooled(
    method: &(dyn GradMethod + Sync),
    dynamics: &(dyn Dynamics + Sync),
    solver: &(dyn Solver + Sync),
    spec: &IvpSpec,
    grid: &ObsGrid,
    z0: &[f32],
    bspec: &BatchSpec,
    loss: &(dyn BatchObsLossHead + Sync),
    tracker: Arc<MemTracker>,
) -> Result<BatchObsGradResult> {
    let workers = pool::num_threads().min(bspec.batch);
    if dynamics.is_device_batched() || workers <= 1 {
        return grad_obs_batched(method, dynamics, solver, spec, grid, z0, bspec, loss, tracker);
    }
    ensure!(
        loss.separable(),
        "pooled batching requires a separable (per-row) observation head; \
         this head couples rows and can only run serially or device-fused"
    );
    ensure!(
        z0.len() == bspec.flat_len(),
        "z0 has {} elements, want [{}, {}]",
        z0.len(),
        bspec.batch,
        bspec.n_z
    );
    // same balanced contiguous split as the serve layer's intra-batch shards
    let shards: Vec<(usize, usize)> = pool::shard_ranges(bspec.batch, workers)
        .filter(|(s, e)| e > s)
        .collect();
    // scoped counter window — see grad_batched_pooled
    let scoped = ScopedDynamics::new(dynamics);
    let dynamics: &(dyn Dynamics + Sync) = &scoped;
    let results: Vec<Result<BatchObsGradResult>> = pool::par_map(&shards, |&(s, e)| {
        let sub = BatchSpec::new(e - s, bspec.n_z);
        method.grad_obs_batch(
            dynamics,
            solver,
            spec,
            grid,
            &z0[s * bspec.n_z..e * bspec.n_z],
            &sub,
            loss,
            tracker.clone(),
        )
    });
    let mut parts = Vec::with_capacity(results.len());
    for r in results {
        parts.push(r?);
    }

    // concatenate shard rows in order; θ, per-obs losses and counts sum
    let mut out = parts.remove(0);
    for part in parts {
        out.loss += part.loss;
        for (acc, l) in out.obs_losses.iter_mut().zip(&part.obs_losses) {
            *acc += l;
        }
        out.z_final.extend(part.z_final);
        crate::tensor::axpy(1.0, &part.grad_theta, &mut out.grad_theta);
        out.grad_z0.extend(part.grad_z0);
        match (&mut out.reconstructed_z0, part.reconstructed_z0) {
            (Some(acc), Some(rec)) => acc.extend(rec),
            (opt, _) => *opt = None,
        }
        out.stats.bwd_steps += part.stats.bwd_steps;
        out.stats.graph_depth = out.stats.graph_depth.max(part.stats.graph_depth);
        out.stats.fwd.n_accepted += part.stats.fwd.n_accepted;
        out.stats.fwd.n_trials += part.stats.fwd.n_trials;
        out.per_sample_fwd.extend(part.per_sample_fwd);
    }
    out.batch = bspec.batch;
    // exact totals from the scoped counters (see grad_batched_pooled)
    out.stats.f_evals = scoped.counters().f_evals.get();
    out.stats.vjp_evals = scoped.counters().vjp_evals.get();
    out.stats.fwd.f_evals = 0;
    out.stats.peak_mem_bytes = tracker.peak_bytes();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::{by_name, ObsSquareLoss, SquareLoss};
    use crate::solvers::by_name as solver_by_name;
    use crate::solvers::dynamics::LinearToy;

    /// Pooled sharding must agree with the serial batched path.
    #[test]
    fn pooled_matches_serial() {
        let toy = LinearToy::new(-0.4, 1);
        let bspec = BatchSpec::new(6, 1);
        let z0: Vec<f32> = vec![1.0, -0.5, 2.0, 0.25, -1.5, 0.8];
        let solver = solver_by_name("alf").unwrap();
        let spec = IvpSpec::fixed(0.0, 1.0, 0.1);
        let method = by_name("mali").unwrap();
        let serial = grad_batched(
            &*method,
            &toy,
            &*solver,
            &spec,
            &z0,
            &bspec,
            &SquareLoss,
            MemTracker::new(),
        )
        .unwrap();
        let pooled = grad_batched_pooled(
            &*method,
            &toy,
            &*solver,
            &spec,
            &z0,
            &bspec,
            &SquareLoss,
            MemTracker::new(),
        )
        .unwrap();
        assert_eq!(pooled.losses.len(), 6);
        for b in 0..6 {
            assert!(
                (pooled.losses[b] - serial.losses[b]).abs() < 1e-12,
                "loss row {b}"
            );
            assert_eq!(pooled.grad_z0[b], serial.grad_z0[b], "grad_z0 row {b}");
        }
        assert!((pooled.grad_theta[0] - serial.grad_theta[0]).abs() < 1e-4);
        assert_eq!(pooled.stats.f_evals, serial.stats.f_evals);
        assert_eq!(
            pooled.stats.fwd.n_accepted,
            serial.stats.fwd.n_accepted
        );
    }

    /// Pooled sharding of the multi-observation path agrees with the
    /// serial batched path per row and per observation.
    #[test]
    fn pooled_obs_matches_serial() {
        let toy = LinearToy::new(-0.4, 1);
        let bspec = BatchSpec::new(6, 1);
        let z0: Vec<f32> = vec![1.0, -0.5, 2.0, 0.25, -1.5, 0.8];
        let solver = solver_by_name("alf").unwrap();
        let spec = IvpSpec::fixed(0.0, 1.0, 0.1);
        let grid = ObsGrid::new(vec![0.5, 1.0]).unwrap();
        let head = ObsSquareLoss { weights: vec![1.0, 0.5] };
        let method = by_name("mali").unwrap();
        let serial = grad_obs_batched(
            &*method,
            &toy,
            &*solver,
            &spec,
            &grid,
            &z0,
            &bspec,
            &head,
            MemTracker::new(),
        )
        .unwrap();
        let pooled = grad_obs_batched_pooled(
            &*method,
            &toy,
            &*solver,
            &spec,
            &grid,
            &z0,
            &bspec,
            &head,
            MemTracker::new(),
        )
        .unwrap();
        assert_eq!(pooled.obs_losses.len(), 2);
        assert!((pooled.loss - serial.loss).abs() < 1e-9 * (1.0 + serial.loss.abs()));
        for k in 0..2 {
            assert!(
                (pooled.obs_losses[k] - serial.obs_losses[k]).abs()
                    < 1e-9 * (1.0 + serial.obs_losses[k].abs()),
                "obs loss {k}"
            );
        }
        for b in 0..6 {
            assert_eq!(pooled.grad_z0[b], serial.grad_z0[b], "grad_z0 row {b}");
            assert_eq!(pooled.z_final[b], serial.z_final[b], "z_final row {b}");
        }
        assert!((pooled.grad_theta[0] - serial.grad_theta[0]).abs() < 1e-4);
        assert_eq!(pooled.stats.f_evals, serial.stats.f_evals);
    }
}
