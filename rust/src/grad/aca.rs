//! ACA — Adaptive Checkpoint Adjoint (Zhuang et al., ICML 2020), the
//! strongest prior baseline.
//!
//! Forward: the accepted trajectory `{(t_i, state_i)}` is checkpointed
//! (search-process trials are discarded — that is ACA's improvement over
//! naive).  Backward: for each accepted step the local computation graph is
//! rebuilt from the stored input state and backpropagated.
//!
//! Memory is `N_z(N_f + N_t)` — accurate like MALI, but the checkpoint
//! store grows linearly with the number of solver steps, which is what
//! makes ImageNet-scale training infeasible for it (paper §4.2).

use super::{
    BatchGradResult, BatchLossHead, BatchObsGradResult, BatchObsLossHead, GradMethod, GradResult,
    GradStats, IvpSpec, LossHead, ObsGrid, ObsGradResult, ObsLossHead,
};
use crate::solvers::batch::{BatchSpec, BatchState};
use crate::solvers::dynamics::Dynamics;
use crate::solvers::integrate::{
    integrate, integrate_batch, integrate_batch_obs, integrate_obs, AcceptedStep,
    BatchAcceptedStep, BatchStepObserver, StepObserver,
};
use crate::solvers::workspace::{BatchWorkspace, SolverWorkspace};
use crate::solvers::{Solver, State};
use crate::tensor::axpy;
use crate::util::mem::{MemTracker, TrackedBuf};
use anyhow::{ensure, Result};
use std::sync::Arc;

pub struct Aca;

/// Observer that checkpoints the *input* state of every accepted step,
/// plus the observation marks `(k, steps_done)` the multi-observation
/// backward replay injects cotangents at.
struct Checkpointer {
    tracker: Arc<MemTracker>,
    /// (t, h, state-before) per accepted step.
    steps: Vec<(f64, f64, State)>,
    marks: Vec<(usize, usize)>,
    bufs: Vec<TrackedBuf>,
}

impl Checkpointer {
    fn new(tracker: Arc<MemTracker>) -> Self {
        Checkpointer {
            tracker,
            steps: Vec::new(),
            marks: Vec::new(),
            bufs: Vec::new(),
        }
    }
}

impl StepObserver for Checkpointer {
    fn on_accept(&mut self, step: &AcceptedStep) {
        // Track the checkpoint bytes (z and, for ALF, v).
        self.bufs.push(TrackedBuf::new(
            step.before.z.clone(),
            self.tracker.clone(),
        ));
        if let Some(v) = &step.before.v {
            self.bufs
                .push(TrackedBuf::new(v.clone(), self.tracker.clone()));
        }
        self.steps
            .push((step.t, step.h, step.before.clone()));
    }

    fn on_observation(&mut self, k: usize, _t: f64, _state: &State) {
        self.marks.push((k, self.steps.len()));
    }
}

/// Batched checkpointer: one `(t, h, state-before)` list per sample — the
/// `N_z(N_f + N_t)` store with `N_z → B·N_z` and per-sample `N_t` — plus
/// per-sample observation marks.
struct BatchCheckpointer {
    tracker: Arc<MemTracker>,
    steps: Vec<Vec<(f64, f64, State)>>,
    marks: Vec<Vec<(usize, usize)>>,
    bufs: Vec<TrackedBuf>,
}

impl BatchCheckpointer {
    fn new(tracker: Arc<MemTracker>, batch: usize) -> Self {
        BatchCheckpointer {
            tracker,
            steps: vec![Vec::new(); batch],
            marks: vec![Vec::new(); batch],
            bufs: Vec::new(),
        }
    }
}

impl BatchStepObserver for BatchCheckpointer {
    fn on_accept(&mut self, step: &BatchAcceptedStep) {
        let before = step.before_state();
        self.bufs
            .push(TrackedBuf::new(before.z.clone(), self.tracker.clone()));
        if let Some(v) = &before.v {
            self.bufs
                .push(TrackedBuf::new(v.clone(), self.tracker.clone()));
        }
        self.steps[step.sample].push((step.t, step.h, before));
    }

    fn on_observation(&mut self, sample: usize, k: usize, _t: f64, _z: &[f32], _v: Option<&[f32]>) {
        self.marks[sample].push((k, self.steps[sample].len()));
    }
}

/// Never-called observation head for replays without observations.
struct NeverObsLoss;

impl ObsLossHead for NeverObsLoss {
    fn loss_grad_at(&self, _k: usize, _t: f64, _z: &[f32]) -> (f64, Vec<f32>) {
        unreachable!("replay without observation marks never evaluates a head")
    }
}

/// Shared by ACA and naive (solo): walk the stored accepted steps
/// backwards, injecting each observation's cotangent — evaluated at the
/// stored forward state — when crossing its mark, accumulating the
/// θ-gradient into `grad_theta` and the per-observation losses into
/// `obs_losses`.  The pulled-back cotangent is left in `a`; the replay
/// ping-pongs `a` against a workspace buffer, so each backward step is
/// allocation-free for dynamics with in-place vjp paths.
#[allow(clippy::too_many_arguments)]
pub(super) fn replay_backward_obs(
    dynamics: &dyn Dynamics,
    solver: &dyn Solver,
    steps: &[(f64, f64, State)],
    marks: &[(usize, usize)],
    grid: &ObsGrid,
    z_end: &[f32],
    loss: &dyn ObsLossHead,
    a: &mut State,
    grad_theta: &mut [f32],
    obs_losses: &mut [f64],
    ws: &mut SolverWorkspace,
) {
    let n = steps.len();
    let mut mp = marks.len();
    let mut a_prev = ws.take_state(a);
    for i in (0..=n).rev() {
        while mp > 0 && marks[mp - 1].1 == i {
            let k = marks[mp - 1].0;
            let z_at: &[f32] = if i == n { z_end } else { &steps[i].2.z };
            let (l, g) = loss.loss_grad_at(k, grid.time(k), z_at);
            obs_losses[k] = l;
            axpy(1.0, &g, &mut a.z);
            mp -= 1;
        }
        if i == 0 {
            break;
        }
        let (t, h, before) = &steps[i - 1];
        solver.step_vjp_into(dynamics, *t, *h, before, a, &mut a_prev, grad_theta, ws);
        std::mem::swap(a, &mut a_prev);
    }
    ws.put_state(a_prev);
}

/// Shared by ACA and naive: replay the per-sample accepted steps backwards
/// in lockstep (rows that run out of steps drop from the gathered
/// sub-batch), accumulating the batch-summed θ-gradient into `grad_theta`
/// and leaving the pulled-back cotangent in `a`.
pub(super) fn replay_backward_batch(
    dynamics: &dyn Dynamics,
    solver: &dyn Solver,
    steps: &[Vec<(f64, f64, State)>],
    a: &mut BatchState,
    grad_theta: &mut [f32],
    ws: &mut BatchWorkspace,
) {
    let no_marks = vec![Vec::new(); steps.len()];
    replay_backward_batch_obs(
        dynamics,
        solver,
        steps,
        &no_marks,
        &ObsGrid::none(),
        &[],
        &NeverObsLoss,
        a,
        grad_theta,
        &mut [],
        ws,
    );
}

/// [`replay_backward_batch`] with per-sample observation marks: each
/// row's due cotangents (evaluated per row at the stored forward state)
/// are injected into `a` before the row's next backward step, and the
/// per-observation losses accumulate batch-summed into `obs_losses`.
/// `z_end` holds the flat `[B, N_z]` terminal states for marks at the end
/// of a row's trajectory.
#[allow(clippy::too_many_arguments)]
pub(super) fn replay_backward_batch_obs(
    dynamics: &dyn Dynamics,
    solver: &dyn Solver,
    steps: &[Vec<(f64, f64, State)>],
    marks: &[Vec<(usize, usize)>],
    grid: &ObsGrid,
    z_end: &[f32],
    loss: &dyn BatchObsLossHead,
    a: &mut BatchState,
    grad_theta: &mut [f32],
    obs_losses: &mut [f64],
    ws: &mut BatchWorkspace,
) {
    let batch = steps.len();
    let spec = a.spec();
    let row_spec = BatchSpec::single(spec.n_z);
    let mut rem: Vec<usize> = steps.iter().map(|s| s.len()).collect();
    let mut mp: Vec<usize> = marks.iter().map(|m| m.len()).collect();
    let mut a_prev = ws.take_batch(spec.batch, spec.n_z, a.v.is_some());
    loop {
        // inject the observation cotangents due at each row's position
        for b in 0..batch {
            while mp[b] > 0 && marks[b][mp[b] - 1].1 == rem[b] {
                let k = marks[b][mp[b] - 1].0;
                let z_at: &[f32] = if rem[b] == steps[b].len() {
                    spec.row(z_end, b)
                } else {
                    &steps[b][rem[b]].2.z
                };
                let (ls, g) = loss.loss_grad_at_batch(k, grid.time(k), z_at, &row_spec);
                obs_losses[k] += ls.iter().sum::<f64>();
                axpy(1.0, &g, spec.row_mut(&mut a.z.data, b));
                mp[b] -= 1;
            }
        }
        let active: Vec<usize> = (0..batch).filter(|&b| rem[b] > 0).collect();
        if active.is_empty() {
            break;
        }
        let mut ts = Vec::with_capacity(active.len());
        let mut hs = Vec::with_capacity(active.len());
        let mut before = Vec::with_capacity(active.len());
        for &b in &active {
            let (t, h, s) = &steps[b][rem[b] - 1];
            ts.push(*t);
            hs.push(*h);
            before.push(s);
        }
        let s_in_sub = BatchState::from_states(&before);
        // skip the cotangent gather/scatter while every row is active
        let full = active.len() == batch;
        if full {
            solver
                .step_vjp_batch_into(dynamics, &ts, &hs, &s_in_sub, a, &mut a_prev, grad_theta, ws);
            std::mem::swap(a, &mut a_prev);
        } else {
            let a_sub = a.gather_rows(&active);
            let (a_prev_sub, dth) = solver.step_vjp_batch(dynamics, &ts, &hs, &s_in_sub, &a_sub);
            axpy(1.0, &dth, grad_theta);
            a.scatter_rows(&a_prev_sub, &active);
        }
        for &b in &active {
            rem[b] -= 1;
        }
    }
    ws.put_batch(a_prev);
}

/// Shared by ACA and naive: the initialisation hop `v₀ = f(z₀, t₀)` for
/// every row whose leftover `a_v(t₀)` carries cotangent (ALF only).
pub(super) fn init_hop_batch(
    dynamics: &dyn Dynamics,
    t0: f64,
    z0: &[f32],
    bspec: &BatchSpec,
    a: &BatchState,
    grad_z0: &mut [f32],
    grad_theta: &mut [f32],
) {
    let Some(av) = &a.v else { return };
    let hop: Vec<usize> = (0..bspec.batch)
        .filter(|&b| bspec.row(&av.data, b).iter().any(|&x| x != 0.0))
        .collect();
    if hop.is_empty() {
        return;
    }
    let sub = bspec.with_batch(hop.len());
    let z_sub = bspec.gather(z0, &hop);
    let av_sub = bspec.gather(&av.data, &hop);
    let ts0 = vec![t0; hop.len()];
    let (gz_sub, gth) = dynamics.f_vjp_batch(&ts0, &z_sub, &av_sub, &sub);
    for (k, &b) in hop.iter().enumerate() {
        axpy(1.0, sub.row(&gz_sub, k), bspec.row_mut(grad_z0, b));
    }
    axpy(1.0, &gth, grad_theta);
}

impl GradMethod for Aca {
    fn name(&self) -> &'static str {
        "aca"
    }

    fn grad(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        z0: &[f32],
        loss: &dyn LossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<GradResult> {
        let c = dynamics.counters();
        c.reset();

        // ---- forward with checkpointing ---------------------------------
        let s0 = solver.init(dynamics, spec.t0, z0);
        let mut ckpt = Checkpointer::new(tracker.clone());
        let (s_end, fwd) = integrate(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, &mut ckpt,
        )?;
        let (loss_val, dl_dz) = loss.loss_grad(&s_end.z);

        // ---- backward: local replay per checkpoint ----------------------
        let mut ws = SolverWorkspace::new();
        let mut a = State {
            z: dl_dz,
            v: s_end.v.as_ref().map(|v| vec![0.0f32; v.len()]),
        };
        let mut a_prev = ws.take_state(&a);
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        for (t, h, before) in ckpt.steps.iter().rev() {
            solver
                .step_vjp_into(dynamics, *t, *h, before, &a, &mut a_prev, &mut grad_theta, &mut ws);
            std::mem::swap(&mut a, &mut a_prev);
        }
        ws.put_state(a_prev);
        // initialisation hop (ALF: v₀ = f(z₀, t₀) depends on z₀ and θ)
        let mut grad_z0 = a.z.clone();
        if let Some(av0) = &a.v {
            if av0.iter().any(|&x| x != 0.0) {
                let first_z = ckpt
                    .steps
                    .first()
                    .map(|(_, _, s)| s.z.as_slice())
                    .unwrap_or(z0);
                let (gz, gth) = dynamics.f_vjp(spec.t0, first_z, av0);
                axpy(1.0, &gz, &mut grad_z0);
                axpy(1.0, &gth, &mut grad_theta);
            }
        }

        let n = ckpt.steps.len();
        let stats = GradStats {
            bwd_steps: n,
            f_evals: c.f_evals.get(),
            vjp_evals: c.vjp_evals.get(),
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * n.max(1),
            fwd,
        };
        Ok(GradResult {
            loss: loss_val,
            z_final: s_end.z,
            grad_theta,
            grad_z0,
            reconstructed_z0: None,
            stats,
        })
    }

    /// Batched ACA: per-sample checkpoints of the accepted steps (the
    /// store grows as `B·N_z·N_t` — what makes large-scale training
    /// infeasible for ACA, now visible at batch scale), then a lockstep
    /// local replay over whichever rows still have checkpoints left.
    #[allow(clippy::too_many_arguments)]
    fn grad_batch(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        z0: &[f32],
        bspec: &BatchSpec,
        loss: &dyn BatchLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<BatchGradResult> {
        let c = dynamics.counters();
        let f0 = c.f_evals.get();
        let v0 = c.vjp_evals.get();

        // ---- forward with per-sample checkpointing ---------------------
        let s0 = solver.init_batch(dynamics, spec.t0, z0, bspec);
        let mut ckpt = BatchCheckpointer::new(tracker.clone(), bspec.batch);
        let (s_end, fwd) = integrate_batch(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, &mut ckpt,
        )?;
        let (losses, dl_dz) = loss.loss_grad_batch(&s_end.z.data, bspec);

        // ---- backward: lockstep local replay ---------------------------
        let mut a = BatchState {
            z: crate::tensor::Tensor::new(dl_dz, vec![bspec.batch, bspec.n_z]),
            v: s_end
                .v
                .as_ref()
                .map(|v| crate::tensor::Tensor::zeros(&v.shape)),
        };
        let mut ws = BatchWorkspace::new();
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        replay_backward_batch(dynamics, solver, &ckpt.steps, &mut a, &mut grad_theta, &mut ws);

        let mut grad_z0 = a.z.data.clone();
        init_hop_batch(dynamics, spec.t0, z0, bspec, &a, &mut grad_z0, &mut grad_theta);

        let n_total: usize = ckpt.steps.iter().map(|s| s.len()).sum();
        let n_max: usize = ckpt.steps.iter().map(|s| s.len()).max().unwrap_or(0);
        let stats = GradStats {
            bwd_steps: n_total,
            f_evals: c.f_evals.get() - f0,
            vjp_evals: c.vjp_evals.get() - v0,
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * n_max.max(1),
            fwd: fwd.aggregate(),
        };
        Ok(BatchGradResult {
            batch: bspec.batch,
            n_z: bspec.n_z,
            loss: losses.iter().sum(),
            losses,
            z_final: s_end.z.data,
            grad_theta,
            grad_z0,
            reconstructed_z0: None,
            stats,
            per_sample_fwd: fwd.per_sample,
        })
    }

    /// Multi-observation ACA: the exact-hit grid makes the accepted steps
    /// *be* the per-segment checkpoint structure (segment boundaries are
    /// accepted times), so one checkpointed forward pass plus the
    /// injection replay reuses the per-segment search behind the shared
    /// interface.
    #[allow(clippy::too_many_arguments)]
    fn grad_obs(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        grid: &ObsGrid,
        z0: &[f32],
        loss: &dyn ObsLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<ObsGradResult> {
        ensure!(
            !grid.is_empty(),
            "empty observation grid; use grad() for a terminal loss"
        );
        let c = dynamics.counters();
        c.reset();

        let s0 = solver.init(dynamics, spec.t0, z0);
        let mut ckpt = Checkpointer::new(tracker.clone());
        let (s_end, fwd) = integrate_obs(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, grid, &mut ckpt,
        )?;

        let mut a = State {
            z: vec![0.0f32; s_end.z.len()],
            v: s_end.v.as_ref().map(|v| vec![0.0f32; v.len()]),
        };
        let mut ws = SolverWorkspace::new();
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        let mut obs_losses = vec![0.0f64; grid.len()];
        replay_backward_obs(
            dynamics,
            solver,
            &ckpt.steps,
            &ckpt.marks,
            grid,
            &s_end.z,
            loss,
            &mut a,
            &mut grad_theta,
            &mut obs_losses,
            &mut ws,
        );
        // initialisation hop (ALF: v₀ = f(z₀, t₀) depends on z₀ and θ)
        let mut grad_z0 = a.z.clone();
        if let Some(av0) = &a.v {
            if av0.iter().any(|&x| x != 0.0) {
                let first_z = ckpt
                    .steps
                    .first()
                    .map(|(_, _, s)| s.z.as_slice())
                    .unwrap_or(z0);
                let (gz, gth) = dynamics.f_vjp(spec.t0, first_z, av0);
                axpy(1.0, &gz, &mut grad_z0);
                axpy(1.0, &gth, &mut grad_theta);
            }
        }

        let n = ckpt.steps.len();
        let stats = GradStats {
            bwd_steps: n,
            f_evals: c.f_evals.get(),
            vjp_evals: c.vjp_evals.get(),
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * n.max(1),
            fwd,
        };
        Ok(ObsGradResult {
            loss: obs_losses.iter().sum(),
            obs_losses,
            z_final: s_end.z,
            grad_theta,
            grad_z0,
            reconstructed_z0: None,
            stats,
        })
    }

    /// Batched multi-observation ACA: per-sample checkpoints + marks, then
    /// the lockstep injection replay.
    #[allow(clippy::too_many_arguments)]
    fn grad_obs_batch(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        grid: &ObsGrid,
        z0: &[f32],
        bspec: &BatchSpec,
        loss: &dyn BatchObsLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<BatchObsGradResult> {
        ensure!(
            !grid.is_empty(),
            "empty observation grid; use grad_batch() for a terminal loss"
        );
        ensure!(
            loss.separable(),
            "batched native injection evaluates the head per row; a fused \
             head must go through batch_driver::grad_obs_batched"
        );
        let c = dynamics.counters();
        let f0 = c.f_evals.get();
        let v0 = c.vjp_evals.get();

        let s0 = solver.init_batch(dynamics, spec.t0, z0, bspec);
        let mut ckpt = BatchCheckpointer::new(tracker.clone(), bspec.batch);
        let (s_end, fwd) = integrate_batch_obs(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, grid, &mut ckpt,
        )?;

        let mut a = BatchState {
            z: crate::tensor::Tensor::zeros(&[bspec.batch, bspec.n_z]),
            v: s_end
                .v
                .as_ref()
                .map(|v| crate::tensor::Tensor::zeros(&v.shape)),
        };
        let mut ws = BatchWorkspace::new();
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        let mut obs_losses = vec![0.0f64; grid.len()];
        replay_backward_batch_obs(
            dynamics,
            solver,
            &ckpt.steps,
            &ckpt.marks,
            grid,
            &s_end.z.data,
            loss,
            &mut a,
            &mut grad_theta,
            &mut obs_losses,
            &mut ws,
        );

        let mut grad_z0 = a.z.data.clone();
        init_hop_batch(dynamics, spec.t0, z0, bspec, &a, &mut grad_z0, &mut grad_theta);

        let n_total: usize = ckpt.steps.iter().map(|s| s.len()).sum();
        let n_max: usize = ckpt.steps.iter().map(|s| s.len()).max().unwrap_or(0);
        let stats = GradStats {
            bwd_steps: n_total,
            f_evals: c.f_evals.get() - f0,
            vjp_evals: c.vjp_evals.get() - v0,
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * n_max.max(1),
            fwd: fwd.aggregate(),
        };
        Ok(BatchObsGradResult {
            batch: bspec.batch,
            n_z: bspec.n_z,
            loss: obs_losses.iter().sum(),
            obs_losses,
            z_final: s_end.z.data,
            grad_theta,
            grad_z0,
            reconstructed_z0: None,
            stats,
            per_sample_fwd: fwd.per_sample,
        })
    }
}
