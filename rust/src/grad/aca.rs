//! ACA — Adaptive Checkpoint Adjoint (Zhuang et al., ICML 2020), the
//! strongest prior baseline.
//!
//! Forward: the accepted trajectory `{(t_i, state_i)}` is checkpointed
//! (search-process trials are discarded — that is ACA's improvement over
//! naive).  Backward: for each accepted step the local computation graph is
//! rebuilt from the stored input state and backpropagated.
//!
//! Memory is `N_z(N_f + N_t)` — accurate like MALI, but the checkpoint
//! store grows linearly with the number of solver steps, which is what
//! makes ImageNet-scale training infeasible for it (paper §4.2).

use super::{GradMethod, GradResult, GradStats, IvpSpec, LossHead};
use crate::solvers::dynamics::Dynamics;
use crate::solvers::integrate::{integrate, AcceptedStep, StepObserver};
use crate::solvers::{Solver, State};
use crate::tensor::axpy;
use crate::util::mem::{MemTracker, TrackedBuf};
use anyhow::Result;
use std::sync::Arc;

pub struct Aca;

/// Observer that checkpoints the *input* state of every accepted step.
struct Checkpointer {
    tracker: Arc<MemTracker>,
    /// (t, h, state-before) per accepted step.
    steps: Vec<(f64, f64, State)>,
    bufs: Vec<TrackedBuf>,
}

impl Checkpointer {
    fn new(tracker: Arc<MemTracker>) -> Self {
        Checkpointer {
            tracker,
            steps: Vec::new(),
            bufs: Vec::new(),
        }
    }
}

impl StepObserver for Checkpointer {
    fn on_accept(&mut self, step: &AcceptedStep) {
        // Track the checkpoint bytes (z and, for ALF, v).
        self.bufs.push(TrackedBuf::new(
            step.before.z.clone(),
            self.tracker.clone(),
        ));
        if let Some(v) = &step.before.v {
            self.bufs
                .push(TrackedBuf::new(v.clone(), self.tracker.clone()));
        }
        self.steps
            .push((step.t, step.h, step.before.clone()));
    }
}

impl GradMethod for Aca {
    fn name(&self) -> &'static str {
        "aca"
    }

    fn grad(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        z0: &[f32],
        loss: &dyn LossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<GradResult> {
        let c = dynamics.counters();
        c.reset();

        // ---- forward with checkpointing ---------------------------------
        let s0 = solver.init(dynamics, spec.t0, z0);
        let mut ckpt = Checkpointer::new(tracker.clone());
        let (s_end, fwd) = integrate(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, &mut ckpt,
        )?;
        let (loss_val, dl_dz) = loss.loss_grad(&s_end.z);

        // ---- backward: local replay per checkpoint ----------------------
        let mut a = State {
            z: dl_dz,
            v: s_end.v.as_ref().map(|v| vec![0.0f32; v.len()]),
        };
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        for (t, h, before) in ckpt.steps.iter().rev() {
            let (a_prev, dth) = solver.step_vjp(dynamics, *t, *h, before, &a);
            axpy(1.0, &dth, &mut grad_theta);
            a = a_prev;
        }
        // initialisation hop (ALF: v₀ = f(z₀, t₀) depends on z₀ and θ)
        let mut grad_z0 = a.z.clone();
        if let Some(av0) = &a.v {
            if av0.iter().any(|&x| x != 0.0) {
                let first_z = ckpt
                    .steps
                    .first()
                    .map(|(_, _, s)| s.z.as_slice())
                    .unwrap_or(z0);
                let (gz, gth) = dynamics.f_vjp(spec.t0, first_z, av0);
                axpy(1.0, &gz, &mut grad_z0);
                axpy(1.0, &gth, &mut grad_theta);
            }
        }

        let n = ckpt.steps.len();
        let stats = GradStats {
            bwd_steps: n,
            f_evals: c.f_evals.get(),
            vjp_evals: c.vjp_evals.get(),
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * n.max(1),
            fwd,
        };
        Ok(GradResult {
            loss: loss_val,
            z_final: s_end.z,
            grad_theta,
            grad_z0,
            reconstructed_z0: None,
            stats,
        })
    }
}
