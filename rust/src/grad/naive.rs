//! Naive backprop-through-the-solver.
//!
//! The whole computation graph — **including every rejected trial of the
//! step-size search** — is kept in memory, exactly like calling
//! `loss.backward()` on an ODE solve written in an eager autodiff
//! framework.  Memory is `N_z·N_f·N_t·m` and the recorded graph depth is
//! `N_f·N_t·m` (paper Table 1), which is what makes the naive method both
//! the most expensive and the most vulnerable to exploding/vanishing
//! gradients.
//!
//! Gradient *values* flow only through the accepted steps (a rejected
//! trial's output is discarded by the control flow; step sizes are not
//! differentiated — the standard autodiff semantics of adaptive solvers),
//! so naive agrees numerically with ACA while paying the full tape.

use super::aca::{
    init_hop_batch, replay_backward_batch, replay_backward_batch_obs, replay_backward_obs,
};
use super::{
    BatchGradResult, BatchLossHead, BatchObsGradResult, BatchObsLossHead, GradMethod, GradResult,
    GradStats, IvpSpec, LossHead, ObsGrid, ObsGradResult, ObsLossHead,
};
use crate::solvers::batch::{BatchSpec, BatchState};
use crate::solvers::dynamics::Dynamics;
use crate::solvers::integrate::{
    integrate, integrate_batch, integrate_batch_obs, integrate_obs, AcceptedStep,
    BatchAcceptedStep, BatchStepObserver, StepObserver,
};
use crate::solvers::workspace::{BatchWorkspace, SolverWorkspace};
use crate::solvers::{Solver, State};
use crate::tensor::axpy;
use crate::util::mem::{MemTracker, TrackedBuf};
use anyhow::{ensure, Result};
use std::sync::Arc;

pub struct Naive;

/// Tape of every trial (accepted or not): the naive autodiff graph.
struct FullTape {
    tracker: Arc<MemTracker>,
    /// Accepted steps: (t, h, state-before).
    accepted: Vec<(f64, f64, State)>,
    /// Observation marks `(k, steps_done)` for cotangent injection.
    marks: Vec<(usize, usize)>,
    /// All retained buffers, including rejected-trial outputs.  Each trial
    /// retains its produced state **times N_f**: an eager framework holds
    /// every layer's activation of `f` per trial — that per-layer factor
    /// is exactly the `N_f` in the paper's `N_z·N_f·N_t·m` (Table 1).
    bufs: Vec<TrackedBuf>,
    /// `N_f` of the dynamics under differentiation.
    nf: usize,
    n_trials: usize,
    /// Graph depth counted over *all* trials.
    depth_units: usize,
}

impl FullTape {
    fn new(tracker: Arc<MemTracker>, nf: usize) -> Self {
        FullTape {
            tracker,
            accepted: Vec::new(),
            marks: Vec::new(),
            bufs: Vec::new(),
            nf,
            n_trials: 0,
            depth_units: 0,
        }
    }
}

impl StepObserver for FullTape {
    fn on_accept(&mut self, step: &AcceptedStep) {
        self.accepted
            .push((step.t, step.h, step.before.clone()));
    }

    fn on_trial(&mut self, _t: f64, _h: f64, state_bytes: usize, _accepted: bool) {
        // Retain the trial's materialized per-layer activations.
        self.bufs.push(TrackedBuf::new(
            vec![0.0f32; (state_bytes / 4) * self.nf],
            self.tracker.clone(),
        ));
        self.n_trials += 1;
        self.depth_units += 1;
    }

    fn on_observation(&mut self, k: usize, _t: f64, _state: &State) {
        self.marks.push((k, self.accepted.len()));
    }
}

/// Batched full tape: per-sample accepted steps plus every trial's
/// per-layer activations — `N_z·N_f·N_t·m` with `N_z → B·N_z` and
/// per-sample `N_t·m` — plus per-sample observation marks.
struct BatchFullTape {
    tracker: Arc<MemTracker>,
    accepted: Vec<Vec<(f64, f64, State)>>,
    marks: Vec<Vec<(usize, usize)>>,
    bufs: Vec<TrackedBuf>,
    nf: usize,
    /// Per-sample trial counts (the naive graph-depth units).
    trial_units: Vec<usize>,
}

impl BatchFullTape {
    fn new(tracker: Arc<MemTracker>, nf: usize, batch: usize) -> Self {
        BatchFullTape {
            tracker,
            accepted: vec![Vec::new(); batch],
            marks: vec![Vec::new(); batch],
            bufs: Vec::new(),
            nf,
            trial_units: vec![0; batch],
        }
    }
}

impl BatchStepObserver for BatchFullTape {
    fn on_accept(&mut self, step: &BatchAcceptedStep) {
        self.accepted[step.sample].push((step.t, step.h, step.before_state()));
    }

    fn on_trial(&mut self, sample: usize, _t: f64, _h: f64, state_bytes: usize, _accepted: bool) {
        self.bufs.push(TrackedBuf::new(
            vec![0.0f32; (state_bytes / 4) * self.nf],
            self.tracker.clone(),
        ));
        self.trial_units[sample] += 1;
    }

    fn on_observation(&mut self, sample: usize, k: usize, _t: f64, _z: &[f32], _v: Option<&[f32]>) {
        self.marks[sample].push((k, self.accepted[sample].len()));
    }
}

impl GradMethod for Naive {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn grad(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        z0: &[f32],
        loss: &dyn LossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<GradResult> {
        let c = dynamics.counters();
        c.reset();

        let s0 = solver.init(dynamics, spec.t0, z0);
        let mut tape = FullTape::new(tracker.clone(), dynamics.depth_nf());
        let (s_end, fwd) = integrate(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, &mut tape,
        )?;
        let (loss_val, dl_dz) = loss.loss_grad(&s_end.z);

        // Backward over the tape's accepted path (rejected branches carry
        // zero cotangent — their outputs feed nothing).
        let mut ws = SolverWorkspace::new();
        let mut a = State {
            z: dl_dz,
            v: s_end.v.as_ref().map(|v| vec![0.0f32; v.len()]),
        };
        let mut a_prev = ws.take_state(&a);
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        for (t, h, before) in tape.accepted.iter().rev() {
            solver
                .step_vjp_into(dynamics, *t, *h, before, &a, &mut a_prev, &mut grad_theta, &mut ws);
            std::mem::swap(&mut a, &mut a_prev);
        }
        ws.put_state(a_prev);
        let mut grad_z0 = a.z.clone();
        if let Some(av0) = &a.v {
            if av0.iter().any(|&x| x != 0.0) {
                let first_z = tape
                    .accepted
                    .first()
                    .map(|(_, _, s)| s.z.as_slice())
                    .unwrap_or(z0);
                let (gz, gth) = dynamics.f_vjp(spec.t0, first_z, av0);
                axpy(1.0, &gz, &mut grad_z0);
                axpy(1.0, &gth, &mut grad_theta);
            }
        }

        let stats = GradStats {
            bwd_steps: tape.accepted.len(),
            f_evals: c.f_evals.get(),
            vjp_evals: c.vjp_evals.get(),
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * tape.depth_units.max(1),
            fwd,
        };
        Ok(GradResult {
            loss: loss_val,
            z_final: s_end.z,
            grad_theta,
            grad_z0,
            reconstructed_z0: None,
            stats,
        })
    }

    /// Batched naive backprop: the full per-sample tape — including every
    /// rejected trial's per-layer activations — is retained at batch
    /// scale, then the accepted path is replayed backwards in lockstep
    /// (gradient values flow only through accepted steps, as in the solo
    /// path).
    #[allow(clippy::too_many_arguments)]
    fn grad_batch(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        z0: &[f32],
        bspec: &BatchSpec,
        loss: &dyn BatchLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<BatchGradResult> {
        let c = dynamics.counters();
        let f0 = c.f_evals.get();
        let v0 = c.vjp_evals.get();

        let s0 = solver.init_batch(dynamics, spec.t0, z0, bspec);
        let mut tape = BatchFullTape::new(tracker.clone(), dynamics.depth_nf(), bspec.batch);
        let (s_end, fwd) = integrate_batch(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, &mut tape,
        )?;
        let (losses, dl_dz) = loss.loss_grad_batch(&s_end.z.data, bspec);

        let mut a = BatchState {
            z: crate::tensor::Tensor::new(dl_dz, vec![bspec.batch, bspec.n_z]),
            v: s_end
                .v
                .as_ref()
                .map(|v| crate::tensor::Tensor::zeros(&v.shape)),
        };
        let mut ws = BatchWorkspace::new();
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        replay_backward_batch(dynamics, solver, &tape.accepted, &mut a, &mut grad_theta, &mut ws);

        let mut grad_z0 = a.z.data.clone();
        init_hop_batch(dynamics, spec.t0, z0, bspec, &a, &mut grad_z0, &mut grad_theta);

        let n_total: usize = tape.accepted.iter().map(|s| s.len()).sum();
        let depth_max: usize = tape.trial_units.iter().copied().max().unwrap_or(0);
        let stats = GradStats {
            bwd_steps: n_total,
            f_evals: c.f_evals.get() - f0,
            vjp_evals: c.vjp_evals.get() - v0,
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * depth_max.max(1),
            fwd: fwd.aggregate(),
        };
        Ok(BatchGradResult {
            batch: bspec.batch,
            n_z: bspec.n_z,
            loss: losses.iter().sum(),
            losses,
            z_final: s_end.z.data,
            grad_theta,
            grad_z0,
            reconstructed_z0: None,
            stats,
            per_sample_fwd: fwd.per_sample,
        })
    }

    /// Multi-observation naive backprop: **one** tape over the whole span
    /// (every trial of every segment retained), with the observation
    /// cotangents injected into the single backward walk at their marks —
    /// no per-segment tape splitting.
    #[allow(clippy::too_many_arguments)]
    fn grad_obs(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        grid: &ObsGrid,
        z0: &[f32],
        loss: &dyn ObsLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<ObsGradResult> {
        ensure!(
            !grid.is_empty(),
            "empty observation grid; use grad() for a terminal loss"
        );
        let c = dynamics.counters();
        c.reset();

        let s0 = solver.init(dynamics, spec.t0, z0);
        let mut tape = FullTape::new(tracker.clone(), dynamics.depth_nf());
        let (s_end, fwd) = integrate_obs(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, grid, &mut tape,
        )?;

        let mut a = State {
            z: vec![0.0f32; s_end.z.len()],
            v: s_end.v.as_ref().map(|v| vec![0.0f32; v.len()]),
        };
        let mut ws = SolverWorkspace::new();
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        let mut obs_losses = vec![0.0f64; grid.len()];
        replay_backward_obs(
            dynamics,
            solver,
            &tape.accepted,
            &tape.marks,
            grid,
            &s_end.z,
            loss,
            &mut a,
            &mut grad_theta,
            &mut obs_losses,
            &mut ws,
        );
        let mut grad_z0 = a.z.clone();
        if let Some(av0) = &a.v {
            if av0.iter().any(|&x| x != 0.0) {
                let first_z = tape
                    .accepted
                    .first()
                    .map(|(_, _, s)| s.z.as_slice())
                    .unwrap_or(z0);
                let (gz, gth) = dynamics.f_vjp(spec.t0, first_z, av0);
                axpy(1.0, &gz, &mut grad_z0);
                axpy(1.0, &gth, &mut grad_theta);
            }
        }

        let stats = GradStats {
            bwd_steps: tape.accepted.len(),
            f_evals: c.f_evals.get(),
            vjp_evals: c.vjp_evals.get(),
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * tape.depth_units.max(1),
            fwd,
        };
        Ok(ObsGradResult {
            loss: obs_losses.iter().sum(),
            obs_losses,
            z_final: s_end.z,
            grad_theta,
            grad_z0,
            reconstructed_z0: None,
            stats,
        })
    }

    /// Batched multi-observation naive backprop: one batched tape with
    /// per-sample marks, then the lockstep injection replay.
    #[allow(clippy::too_many_arguments)]
    fn grad_obs_batch(
        &self,
        dynamics: &dyn Dynamics,
        solver: &dyn Solver,
        spec: &IvpSpec,
        grid: &ObsGrid,
        z0: &[f32],
        bspec: &BatchSpec,
        loss: &dyn BatchObsLossHead,
        tracker: Arc<MemTracker>,
    ) -> Result<BatchObsGradResult> {
        ensure!(
            !grid.is_empty(),
            "empty observation grid; use grad_batch() for a terminal loss"
        );
        ensure!(
            loss.separable(),
            "batched native injection evaluates the head per row; a fused \
             head must go through batch_driver::grad_obs_batched"
        );
        let c = dynamics.counters();
        let f0 = c.f_evals.get();
        let v0 = c.vjp_evals.get();

        let s0 = solver.init_batch(dynamics, spec.t0, z0, bspec);
        let mut tape = BatchFullTape::new(tracker.clone(), dynamics.depth_nf(), bspec.batch);
        let (s_end, fwd) = integrate_batch_obs(
            solver, dynamics, spec.t0, spec.t1, s0, &spec.mode, &spec.norm, grid, &mut tape,
        )?;

        let mut a = BatchState {
            z: crate::tensor::Tensor::zeros(&[bspec.batch, bspec.n_z]),
            v: s_end
                .v
                .as_ref()
                .map(|v| crate::tensor::Tensor::zeros(&v.shape)),
        };
        let mut ws = BatchWorkspace::new();
        let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
        let mut obs_losses = vec![0.0f64; grid.len()];
        replay_backward_batch_obs(
            dynamics,
            solver,
            &tape.accepted,
            &tape.marks,
            grid,
            &s_end.z.data,
            loss,
            &mut a,
            &mut grad_theta,
            &mut obs_losses,
            &mut ws,
        );

        let mut grad_z0 = a.z.data.clone();
        init_hop_batch(dynamics, spec.t0, z0, bspec, &a, &mut grad_z0, &mut grad_theta);

        let n_total: usize = tape.accepted.iter().map(|s| s.len()).sum();
        let depth_max: usize = tape.trial_units.iter().copied().max().unwrap_or(0);
        let stats = GradStats {
            bwd_steps: n_total,
            f_evals: c.f_evals.get() - f0,
            vjp_evals: c.vjp_evals.get() - v0,
            peak_mem_bytes: tracker.peak_bytes(),
            graph_depth: dynamics.depth_nf() * depth_max.max(1),
            fwd: fwd.aggregate(),
        };
        Ok(BatchObsGradResult {
            batch: bspec.batch,
            n_z: bspec.n_z,
            loss: obs_losses.iter().sum(),
            obs_losses,
            z_final: s_end.z.data,
            grad_theta,
            grad_z0,
            reconstructed_z0: None,
            stats,
            per_sample_fwd: fwd.per_sample,
        })
    }
}
