//! Natural cubic splines — the control-path substrate for Neural CDEs
//! (Kidger et al. 2020b; paper Table 5).
//!
//! A Neural CDE consumes `dz = f_θ(z)·dX(t)` where `X(t)` interpolates the
//! irregular observations.  The standard construction is a natural cubic
//! spline per channel.  We fit coefficients here (tridiagonal solve on the
//! host — this is data preparation, not model compute); the spline is
//! *evaluated* inside the exported JAX graph on the device, and the two
//! implementations are cross-checked in the integration tests.

/// Natural cubic spline through `(xs[i], ys[i])`, `xs` strictly increasing.
/// Piece `i` over `[x_i, x_{i+1}]`:
/// `s_i(t) = a_i + b_i·u + c_i·u² + d_i·u³`, `u = t − x_i`.
#[derive(Debug, Clone)]
pub struct CubicSpline {
    pub xs: Vec<f64>,
    pub a: Vec<f64>,
    pub b: Vec<f64>,
    pub c: Vec<f64>,
    pub d: Vec<f64>,
}

impl CubicSpline {
    /// Fit a natural spline (second derivative zero at both ends).
    pub fn fit(xs: &[f64], ys: &[f64]) -> CubicSpline {
        let n = xs.len();
        assert!(n >= 2, "spline needs at least two knots");
        assert_eq!(xs.len(), ys.len());
        for w in xs.windows(2) {
            assert!(w[1] > w[0], "spline knots must be strictly increasing");
        }
        if n == 2 {
            // linear segment
            let h = xs[1] - xs[0];
            return CubicSpline {
                xs: xs.to_vec(),
                a: vec![ys[0]],
                b: vec![(ys[1] - ys[0]) / h],
                c: vec![0.0],
                d: vec![0.0],
            };
        }
        let m = n - 1; // number of pieces
        let h: Vec<f64> = (0..m).map(|i| xs[i + 1] - xs[i]).collect();

        // Solve for second derivatives σ at the knots: natural BCs σ₀ = σ_{n-1} = 0.
        // Tridiagonal system over interior knots (Thomas algorithm).
        let dim = n - 2;
        let mut sigma = vec![0.0f64; n];
        if dim > 0 {
            let mut diag = vec![0.0f64; dim];
            let mut upper = vec![0.0f64; dim];
            let mut lower = vec![0.0f64; dim];
            let mut rhs = vec![0.0f64; dim];
            for i in 0..dim {
                let k = i + 1; // knot index
                diag[i] = 2.0 * (h[k - 1] + h[k]);
                lower[i] = h[k - 1];
                upper[i] = h[k];
                rhs[i] = 6.0
                    * ((ys[k + 1] - ys[k]) / h[k] - (ys[k] - ys[k - 1]) / h[k - 1]);
            }
            // forward sweep
            for i in 1..dim {
                let w = lower[i] / diag[i - 1];
                diag[i] -= w * upper[i - 1];
                rhs[i] -= w * rhs[i - 1];
            }
            // back substitution
            sigma[dim] = rhs[dim - 1] / diag[dim - 1];
            for i in (1..dim).rev() {
                sigma[i] = (rhs[i - 1] - upper[i - 1] * sigma[i + 1]) / diag[i - 1];
            }
        }

        let mut a = vec![0.0f64; m];
        let mut b = vec![0.0f64; m];
        let mut c = vec![0.0f64; m];
        let mut d = vec![0.0f64; m];
        for i in 0..m {
            a[i] = ys[i];
            c[i] = sigma[i] / 2.0;
            d[i] = (sigma[i + 1] - sigma[i]) / (6.0 * h[i]);
            b[i] = (ys[i + 1] - ys[i]) / h[i] - h[i] * (2.0 * sigma[i] + sigma[i + 1]) / 6.0;
        }
        CubicSpline {
            xs: xs.to_vec(),
            a,
            b,
            c,
            d,
        }
    }

    fn piece(&self, t: f64) -> usize {
        let m = self.a.len();
        // binary search for the piece containing t; clamp outside the domain
        match self
            .xs
            .binary_search_by(|x| x.partial_cmp(&t).unwrap())
        {
            Ok(i) => i.min(m - 1),
            Err(0) => 0,
            Err(i) => (i - 1).min(m - 1),
        }
    }

    /// Spline value X(t) (linear extrapolation outside the knot range).
    pub fn eval(&self, t: f64) -> f64 {
        let i = self.piece(t);
        let u = t - self.xs[i];
        self.a[i] + u * (self.b[i] + u * (self.c[i] + u * self.d[i]))
    }

    /// Spline derivative Ẋ(t) — the CDE driver.
    pub fn deriv(&self, t: f64) -> f64 {
        let i = self.piece(t);
        let u = t - self.xs[i];
        self.b[i] + u * (2.0 * self.c[i] + 3.0 * u * self.d[i])
    }

    /// Flatten per-piece coefficients `[a, b, c, d]` (row per piece) — the
    /// ctx tensor layout consumed by the exported CDE graphs.
    pub fn coeffs_flat(&self) -> Vec<f32> {
        let m = self.a.len();
        let mut out = Vec::with_capacity(4 * m);
        for i in 0..m {
            out.push(self.a[i] as f32);
            out.push(self.b[i] as f32);
            out.push(self.c[i] as f32);
            out.push(self.d[i] as f32);
        }
        out
    }
}

/// Multi-channel spline path X: ℝ → ℝ^C over a shared time grid.
#[derive(Debug, Clone)]
pub struct SplinePath {
    pub channels: Vec<CubicSpline>,
}

impl SplinePath {
    /// `ys[c]` is channel c's observations over the shared grid `xs`.
    pub fn fit(xs: &[f64], ys: &[Vec<f64>]) -> SplinePath {
        SplinePath {
            channels: ys.iter().map(|y| CubicSpline::fit(xs, y)).collect(),
        }
    }

    pub fn dim(&self) -> usize {
        self.channels.len()
    }

    pub fn eval(&self, t: f64) -> Vec<f64> {
        self.channels.iter().map(|s| s.eval(t)).collect()
    }

    pub fn deriv(&self, t: f64) -> Vec<f64> {
        self.channels.iter().map(|s| s.deriv(t)).collect()
    }

    /// Stacked coefficient tensor: `[channels × pieces × 4]` flattened, the
    /// layout the exported CDE dynamics graph indexes with `floor` lookup.
    pub fn coeffs_flat(&self) -> Vec<f32> {
        self.channels
            .iter()
            .flat_map(|c| c.coeffs_flat())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpolates_knots_exactly() {
        let xs = [0.0, 1.0, 2.5, 3.0, 4.2];
        let ys = [1.0, -0.5, 2.0, 0.0, 1.5];
        let s = CubicSpline::fit(&xs, &ys);
        for (x, y) in xs.iter().zip(&ys) {
            assert!((s.eval(*x) - y).abs() < 1e-10, "at {x}");
        }
    }

    #[test]
    fn reproduces_linear_functions_exactly() {
        let xs: Vec<f64> = (0..7).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x - 2.0).collect();
        let s = CubicSpline::fit(&xs, &ys);
        for t in [0.3, 2.71, 5.9] {
            assert!((s.eval(t) - (3.0 * t - 2.0)).abs() < 1e-9);
            assert!((s.deriv(t) - 3.0).abs() < 1e-9);
        }
    }

    #[test]
    fn c1_continuity_at_knots() {
        let xs = [0.0, 0.7, 1.3, 2.0, 3.1];
        let ys = [0.0, 1.0, -1.0, 0.5, 2.0];
        let s = CubicSpline::fit(&xs, &ys);
        for &x in &xs[1..xs.len() - 1] {
            let eps = 1e-7;
            let dv_l = s.deriv(x - eps);
            let dv_r = s.deriv(x + eps);
            assert!((dv_l - dv_r).abs() < 1e-4, "kink at {x}: {dv_l} vs {dv_r}");
        }
    }

    #[test]
    fn natural_boundary_second_derivative_zero() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [0.0, 2.0, -1.0, 1.0];
        let s = CubicSpline::fit(&xs, &ys);
        // numerical 2nd derivative at the ends ≈ 0
        let dd = |t: f64| {
            let e = 1e-4;
            (s.eval(t + e) - 2.0 * s.eval(t) + s.eval(t - e)) / (e * e)
        };
        assert!(dd(xs[0] + 2e-4).abs() < 0.05, "{}", dd(xs[0] + 2e-4));
        assert!(dd(xs[3] - 2e-4).abs() < 0.05);
    }

    #[test]
    fn derivative_matches_finite_difference() {
        let xs = [0.0, 0.5, 1.1, 2.0, 2.9, 4.0];
        let ys = [0.3, -0.2, 0.8, 1.1, -0.4, 0.0];
        let s = CubicSpline::fit(&xs, &ys);
        for t in [0.2, 0.9, 1.7, 3.3] {
            let e = 1e-6;
            let fd = (s.eval(t + e) - s.eval(t - e)) / (2.0 * e);
            assert!((s.deriv(t) - fd).abs() < 1e-4, "t={t}");
        }
    }

    #[test]
    fn multichannel_path() {
        let xs: Vec<f64> = (0..5).map(|i| i as f64).collect();
        let ys = vec![
            xs.iter().map(|x| x.sin()).collect::<Vec<_>>(),
            xs.iter().map(|x| x * x).collect::<Vec<_>>(),
        ];
        let p = SplinePath::fit(&xs, &ys);
        assert_eq!(p.dim(), 2);
        let v = p.eval(1.0);
        assert!((v[0] - 1f64.sin()).abs() < 1e-10);
        assert!((v[1] - 1.0).abs() < 1e-10);
        assert_eq!(p.coeffs_flat().len(), 2 * 4 * 4);
    }

    #[test]
    fn two_knot_fallback_is_linear() {
        let s = CubicSpline::fit(&[0.0, 2.0], &[1.0, 5.0]);
        assert!((s.eval(1.0) - 3.0).abs() < 1e-12);
        assert!((s.deriv(1.7) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_decreasing_knots() {
        CubicSpline::fit(&[0.0, 1.0, 0.5], &[0.0, 1.0, 2.0]);
    }
}
