//! Logical memory accounting for gradient methods.
//!
//! Paper Table 1 compares methods by the solver state they must keep alive:
//! naive `N_z·N_f·N_t·m`, adjoint `N_z·N_f`, ACA `N_z(N_f+N_t)`, MALI
//! `N_z(N_f+1)`.  `MemTracker` measures exactly that quantity empirically —
//! every buffer a gradient method retains between the forward and backward
//! pass registers its size here; the peak is reported in Fig-4(c) and the
//! Table-1 validation bench, and enforced against the ImageNet-scale memory
//! budget in the coordinator (the paper's "infeasible to train" gate).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Thread-safe byte counter with peak tracking.
#[derive(Debug, Default)]
pub struct MemTracker {
    live: AtomicUsize,
    peak: AtomicUsize,
    /// Cumulative bytes ever allocated (turnover diagnostics).
    total: AtomicUsize,
}

impl MemTracker {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn alloc(&self, bytes: usize) {
        let now = self.live.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.total.fetch_add(bytes, Ordering::Relaxed);
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    pub fn free(&self, bytes: usize) {
        let prev = self.live.fetch_sub(bytes, Ordering::Relaxed);
        debug_assert!(prev >= bytes, "MemTracker underflow: free {bytes} from {prev}");
    }

    pub fn live_bytes(&self) -> usize {
        self.live.load(Ordering::Relaxed)
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn total_bytes(&self) -> usize {
        self.total.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.live.store(0, Ordering::Relaxed);
        self.peak.store(0, Ordering::Relaxed);
        self.total.store(0, Ordering::Relaxed);
    }
}

/// RAII guard: a tracked buffer of `f32`s.  Gradient methods hold their
/// checkpoints / tapes in these so accounting can't drift from reality.
#[derive(Debug)]
pub struct TrackedBuf {
    pub data: Vec<f32>,
    tracker: Arc<MemTracker>,
}

impl TrackedBuf {
    pub fn new(data: Vec<f32>, tracker: Arc<MemTracker>) -> Self {
        tracker.alloc(data.len() * 4);
        TrackedBuf { data, tracker }
    }
}

impl Drop for TrackedBuf {
    fn drop(&mut self) {
        self.tracker.free(self.data.len() * 4);
    }
}

/// Current process resident-set size in bytes (Linux), for the end-to-end
/// runs recorded in EXPERIMENTS.md.  Returns 0 if /proc is unavailable.
pub fn process_rss_bytes() -> usize {
    let Ok(statm) = std::fs::read_to_string("/proc/self/statm") else {
        return 0;
    };
    let rss_pages: usize = statm
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    rss_pages * 4096
}

/// Human-readable byte formatting for reports.
pub fn fmt_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_live_and_peak() {
        let t = MemTracker::new();
        t.alloc(100);
        t.alloc(50);
        assert_eq!(t.live_bytes(), 150);
        t.free(100);
        assert_eq!(t.live_bytes(), 50);
        assert_eq!(t.peak_bytes(), 150);
        assert_eq!(t.total_bytes(), 150);
    }

    #[test]
    fn tracked_buf_raii() {
        let t = MemTracker::new();
        {
            let _b = TrackedBuf::new(vec![0f32; 256], t.clone());
            assert_eq!(t.live_bytes(), 1024);
            let _c = TrackedBuf::new(vec![0f32; 256], t.clone());
            assert_eq!(t.live_bytes(), 2048);
        }
        assert_eq!(t.live_bytes(), 0);
        assert_eq!(t.peak_bytes(), 2048);
    }

    #[test]
    fn rss_is_positive_on_linux() {
        assert!(process_rss_bytes() > 0);
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
