//! Infrastructure substrates (offline image: hand-rolled, no external crates
//! beyond `xla`/`anyhow`): JSON, RNG, memory accounting, logging, thread
//! pool, bench harness.

pub mod bench;
pub mod json;
pub mod logging;
pub mod mem;
pub mod pool;
pub mod rng;
