//! Minimal JSON parser / writer.
//!
//! The offline image vendors only the `xla` crate's dependency closure, so
//! `serde`/`serde_json` are unavailable; this module is the substrate the
//! coordinator uses for `artifacts/manifest.json`, `configs/*.json` and
//! metrics JSONL output.  It implements the full JSON grammar (RFC 8259)
//! minus `\u` surrogate-pair edge cases beyond the BMP.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.  Object keys are ordered (BTreeMap) so serialized
/// output is deterministic — handy for golden tests and diffable metrics.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- typed accessors -------------------------------------------------

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; returns `Json::Null` for missing keys so lookups
    /// chain without panicking.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup with the same chaining behaviour as [`Json::get`].
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- parse -----------------------------------------------------------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json, JsonError> {
        let text = std::fs::read_to_string(path).map_err(|e| JsonError {
            msg: format!("read {}: {e}", path.display()),
            pos: 0,
        })?;
        Json::parse(&text)
    }

    // ---- write -----------------------------------------------------------

    /// Compact single-line serialization (JSONL-friendly).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed serialization with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => out.push_str(&fmt_num(*n)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

/// Format a number the way JSON expects: integers without a trailing `.0`,
/// everything else via shortest-roundtrip float formatting.
fn fmt_num(n: f64) -> String {
    if !n.is_finite() {
        // JSON has no Inf/NaN; emit null like most tolerant writers.
        return "null".to_string();
    }
    if n.fract() == 0.0 && n.abs() < 9e15 {
        format!("{}", n as i64)
    } else {
        format!("{n}")
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        match char::from_u32(cp) {
                            Some(c) => s.push(c),
                            None => s.push('\u{FFFD}'),
                        }
                    }
                    _ => return Err(self.err("invalid escape sequence")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8: back up and take the full
                    // character from the source slice.
                    let start = self.pos - 1;
                    let width = utf8_width(b);
                    let end = (start + width).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8 in string"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit in \\u escape"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_width(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").idx(0).as_f64(), Some(1.0));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""line\nquote\" tab\t uA π""#).unwrap();
        assert_eq!(v.as_str(), Some("line\nquote\" tab\t uA π"));
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,"s"],"flag":true,"nested":{"k":null}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.dump();
        assert_eq!(Json::parse(&out).unwrap(), v);
        // pretty output reparses to the same value too
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(3.0).dump(), "3");
        assert_eq!(Json::Num(3.25).dump(), "3.25");
    }

    #[test]
    fn chained_lookup_is_total() {
        let v = Json::parse("{}").unwrap();
        assert!(v.get("missing").idx(7).get("nope").is_null());
    }
}
