//! Micro/macro benchmark harness (criterion is not vendored offline).
//!
//! Provides (a) `time_it`: warmup + repeated timing with mean/std/min, and
//! (b) `Table`: aligned ASCII tables so each `benches/*.rs` prints the same
//! rows/series the paper's tables and figures report.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Timing {
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub iters: usize,
}

impl Timing {
    pub fn fmt_ms(&self) -> String {
        format!("{:.3} ms ± {:.3}", self.mean_s * 1e3, self.std_s * 1e3)
    }
}

/// Run `f` for `warmup` untimed and `iters` timed iterations.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(&samples)
}

/// Adaptive variant: keeps timing until `min_time_s` of samples accumulate
/// (at least 3 iterations) — matches criterion's behaviour loosely.
pub fn time_until<F: FnMut()>(min_time_s: f64, mut f: F) -> Timing {
    f(); // warmup
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < min_time_s || samples.len() < 3 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() > 10_000 {
            break;
        }
    }
    summarize(&samples)
}

/// Exact quantile `q ∈ [0, 1]` of raw samples: nearest-rank on a copy
/// sorted by IEEE total order (`f64::total_cmp`, so NaN inputs land at
/// the ends instead of breaking the sort).  The serving bench (E12) uses
/// this for client-observed p50/p99 latency — exact, unlike the workers'
/// online log-bucket histograms ([`crate::serve::LatencyHistogram`]).
pub fn quantile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    let rank = ((q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize).max(1);
    sorted[rank - 1]
}

fn summarize(samples: &[f64]) -> Timing {
    let n = samples.len().max(1) as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    Timing {
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        iters: samples.len(),
    }
}

/// Aligned ASCII table writer used by every bench binary.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn print(&self) {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        println!("\n== {} ==", self.title);
        println!("{sep}");
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(c, s)| format!(" {:<width$} ", s, width = widths[c]))
                .collect::<Vec<_>>()
                .join("|")
        };
        println!("{}", fmt_row(&self.header));
        println!("{sep}");
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        println!("{sep}");
    }
}

/// Print a (x, series...) line chart as aligned columns — the "figure"
/// analogue for terminal output (series data also lands in runs/*.jsonl for
/// real plotting).
pub fn print_series(title: &str, x_label: &str, xs: &[f64], series: &[(&str, Vec<f64>)]) {
    let mut header = vec![x_label];
    for (name, _) in series {
        header.push(name);
    }
    let mut t = Table::new(title, &header);
    for (i, &x) in xs.iter().enumerate() {
        let mut row = vec![format!("{x:.4}")];
        for (_, ys) in series {
            row.push(
                ys.get(i)
                    .map(|y| format!("{y:.6e}"))
                    .unwrap_or_else(|| "-".into()),
            );
        }
        t.row(&row);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_reports_positive() {
        let t = time_it(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t.mean_s >= 0.0);
        assert_eq!(t.iters, 5);
        assert!(t.min_s <= t.mean_s + 1e-12);
    }

    #[test]
    fn table_roundtrip_does_not_panic() {
        let mut t = Table::new("test", &["a", "bb"]);
        t.row_strs(&["1", "2"]);
        t.row(&["x".to_string(), "yyyy".to_string()]);
        t.print();
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new("test", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn quantile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(quantile(&xs, 0.5), 50.0);
        assert_eq!(quantile(&xs, 0.99), 99.0);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 100.0);
        // order-independent
        let mut rev = xs.clone();
        rev.reverse();
        assert_eq!(quantile(&rev, 0.5), 50.0);
        assert_eq!(quantile(&[], 0.5), 0.0);
        assert_eq!(quantile(&[7.0], 0.99), 7.0);
    }
}
