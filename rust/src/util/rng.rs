//! Deterministic pseudo-random numbers and parameter initializers.
//!
//! The coordinator owns all stochastic state (dataset generation, parameter
//! init, dequantization noise, Hutchinson probes, FGSM batches), so every
//! experiment is reproducible from a single `u64` seed recorded in the run
//! log.  Implementation: xoshiro256** seeded via SplitMix64 — fast, solid
//! statistical quality, no external crates.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the 256-bit state; never
        // produces the all-zero state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Derive an independent stream (for per-worker / per-component rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (n << 2^64 so modulo
        // bias is negligible, but keep the multiply-shift trick anyway).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (polar form avoided: trig is fine).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Rademacher ±1 (Hutchinson probes).
    pub fn rademacher(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fill a slice with iid N(0, std).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f64) {
        for x in out.iter_mut() {
            *x = (self.normal() * std) as f32;
        }
    }

    /// Fill a slice with iid U(-a, a).
    pub fn fill_uniform_sym(&mut self, out: &mut [f32], a: f64) {
        for x in out.iter_mut() {
            *x = self.range(-a, a) as f32;
        }
    }

    /// Fisher–Yates shuffle of indices.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

/// Parameter init schemes, matching the manifest's `init` field emitted by
/// `python/compile/aot.py`.  The python side never materializes parameters —
/// Rust owns them end-to-end.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Init {
    /// He/Kaiming normal: N(0, sqrt(2 / fan_in)).
    HeNormal { fan_in: usize },
    /// Glorot/Xavier uniform: U(±sqrt(6 / (fan_in + fan_out))).
    GlorotUniform { fan_in: usize, fan_out: usize },
    /// Small normal with explicit std (e.g. final layers of flows).
    Normal { std: f64 },
    Zeros,
    Ones,
}

impl Init {
    pub fn fill(&self, rng: &mut Rng, out: &mut [f32]) {
        match *self {
            Init::HeNormal { fan_in } => {
                rng.fill_normal(out, (2.0 / fan_in.max(1) as f64).sqrt())
            }
            Init::GlorotUniform { fan_in, fan_out } => {
                let a = (6.0 / (fan_in + fan_out).max(1) as f64).sqrt();
                rng.fill_uniform_sym(out, a)
            }
            Init::Normal { std } => rng.fill_normal(out, std),
            Init::Zeros => out.iter_mut().for_each(|x| *x = 0.0),
            Init::Ones => out.iter_mut().for_each(|x| *x = 1.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(2);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = rng.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(4);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct() {
        let mut rng = Rng::new(5);
        let picks = rng.choose_k(100, 30);
        assert_eq!(picks.len(), 30);
        let mut s = picks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 30);
    }

    #[test]
    fn he_init_variance() {
        let mut rng = Rng::new(6);
        let fan_in = 128;
        let mut buf = vec![0f32; 100_000];
        Init::HeNormal { fan_in }.fill(&mut rng, &mut buf);
        let var: f64 =
            buf.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / buf.len() as f64;
        let expect = 2.0 / fan_in as f64;
        assert!((var / expect - 1.0).abs() < 0.05, "var {var} expect {expect}");
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
