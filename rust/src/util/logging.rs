//! Run logging and metrics emission.
//!
//! Every experiment writes (a) human-readable progress to stderr and (b) a
//! metrics JSONL stream (`runs/<name>.jsonl`) that EXPERIMENTS.md tables and
//! figures are generated from.  No external logging crates in the offline
//! image — this is the substrate.

use crate::util::json::Json;
use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Instant;

/// Verbosity levels for stderr output.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug,
    Info,
    Warn,
    Error,
}

static MIN_LEVEL: Mutex<Level> = Mutex::new(Level::Info);

pub fn set_level(level: Level) {
    *MIN_LEVEL.lock().unwrap() = level;
}

pub fn log(level: Level, msg: &str) {
    if level >= *MIN_LEVEL.lock().unwrap() {
        let tag = match level {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        };
        eprintln!("[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! debug_ {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, &format!($($arg)*)) };
}

/// A metrics sink: append-only JSONL, one record per event, with the
/// wall-clock offset since run start stamped on every record.
pub struct RunLog {
    file: Mutex<File>,
    pub path: PathBuf,
    start: Instant,
}

impl RunLog {
    /// Create `runs/<name>.jsonl` (truncating any previous run of the same
    /// name) under `dir`.
    pub fn create(dir: &str, name: &str) -> std::io::Result<RunLog> {
        fs::create_dir_all(dir)?;
        let path = PathBuf::from(dir).join(format!("{name}.jsonl"));
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&path)?;
        Ok(RunLog {
            file: Mutex::new(file),
            path,
            start: Instant::now(),
        })
    }

    /// Append one record; `fields` are merged with `t_wall` seconds.
    pub fn emit(&self, event: &str, fields: Vec<(&str, Json)>) {
        let mut pairs = vec![
            ("event", Json::Str(event.to_string())),
            ("t_wall", Json::Num(self.start.elapsed().as_secs_f64())),
        ];
        pairs.extend(fields);
        let line = Json::obj(pairs).dump();
        let mut f = self.file.lock().unwrap();
        let _ = writeln!(f, "{line}");
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runlog_writes_jsonl() {
        let dir = std::env::temp_dir().join("mali_log_test");
        let dir = dir.to_str().unwrap();
        let log = RunLog::create(dir, "unit").unwrap();
        log.emit("step", vec![("loss", Json::Num(1.5)), ("epoch", Json::Num(0.0))]);
        log.emit("step", vec![("loss", Json::Num(1.2)), ("epoch", Json::Num(1.0))]);
        let text = std::fs::read_to_string(&log.path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let rec = Json::parse(lines[1]).unwrap();
        assert_eq!(rec.get("event").as_str(), Some("step"));
        assert_eq!(rec.get("loss").as_f64(), Some(1.2));
        assert!(rec.get("t_wall").as_f64().unwrap() >= 0.0);
    }
}
