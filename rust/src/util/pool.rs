//! Data-parallel helpers over std scoped threads, plus the persistent
//! [`WorkerPool`] used by the allocation-free intra-batch sharding path.
//!
//! Neither tokio nor rayon is vendored in the offline image; training-time
//! parallelism here is simple fork-join over batch shards.  The PJRT CPU
//! client serializes device compute anyway, so the coordinator parallelizes
//! the host-side work (data synthesis, metric reduction, multi-seed runs)
//! and keeps device calls on the caller thread.
//!
//! Two dispatch families coexist on purpose (DESIGN §9):
//!
//! * [`par_map`] / [`par_chunks_mut`] — scoped-thread fork-join for cold
//!   coordinator/grad paths.  `thread::spawn` heap-allocates, which is fine
//!   once per experiment shard but banned inside the serve loop.
//! * [`WorkerPool`] — threads spawned **once**, parked on a condvar, handed
//!   work through a pre-installed job slot.  A warmed [`WorkerPool::run`]
//!   dispatch performs zero heap allocations (futex-backed `Mutex`/`Condvar`
//!   on Linux allocate nothing), so sharded integrate/serve stay inside the
//!   zero-allocation contract pinned by `tests/alloc_serve.rs`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

/// Contiguous `[start, end)` index ranges splitting `items` into `shards`
/// near-equal parts: the first `items % shards` ranges get one extra item,
/// so ranges are contiguous, ordered and cover `0..items` exactly.  Trailing
/// ranges are empty when `shards > items` — callers skip those.  This is the
/// single sharding policy shared by the grad batch driver and the serve
/// layer's intra-batch shards.
pub fn shard_ranges(items: usize, shards: usize) -> impl Iterator<Item = (usize, usize)> {
    let s = shards.max(1);
    let base = items / s;
    let extra = items % s;
    (0..s).scan(0usize, move |start, i| {
        let len = base + usize::from(i < extra);
        let r = (*start, *start + len);
        *start += len;
        Some(r)
    })
}

/// Hands out *disjoint* `&mut` sub-ranges of one slice to concurrent shard
/// workers (the safe-Rust alternative — `chunks_mut` — cannot be indexed by
/// an arbitrary `(start, end)` from inside a `Fn` closure shared across
/// threads).
///
/// The soundness contract is the sharding driver's dispatch discipline:
/// every job index is claimed exactly once per [`WorkerPool::run`] call, and
/// the driver derives each job's range from [`shard_ranges`], so no two
/// live borrows overlap and all borrows end before `run` returns (it joins
/// on job completion).
pub struct DisjointRowsMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: a DisjointRowsMut is only a (pointer, len) view; sending/sharing
// it is safe exactly when sending `&mut [T]` would be, i.e. `T: Send`.
// Aliasing is excluded by the `range` contract below.
unsafe impl<T: Send> Send for DisjointRowsMut<'_, T> {}
unsafe impl<T: Send> Sync for DisjointRowsMut<'_, T> {}

impl<'a, T> DisjointRowsMut<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        DisjointRowsMut {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: std::marker::PhantomData,
        }
    }

    /// Borrow `[start, end)` mutably.
    ///
    /// # Safety
    ///
    /// Across all concurrently-live borrows from this view, ranges must be
    /// pairwise disjoint, and every borrow must end before the `&'a mut`
    /// source borrow does.  The sharding drivers guarantee this by taking
    /// each shard's range exactly once per dispatch.
    pub unsafe fn range(&self, start: usize, end: usize) -> &'a mut [T] {
        assert!(start <= end && end <= self.len, "range out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(start), end - start)
    }
}

/// A persistent fork-join pool: `threads` workers spawned at construction,
/// parked on a condvar between dispatches.  [`WorkerPool::run`] publishes a
/// job (`f`, `n_jobs`), wakes the workers, and **participates itself** —
/// caller and workers claim job indices from a shared counter until none
/// remain, then `run` blocks until in-flight jobs finish.  With
/// `threads == 0` the pool is a plain sequential loop on the caller thread
/// (the `MALI_THREADS=1` leg), bitwise-identical by construction.
///
/// A worker panic is caught, recorded, and re-raised on the caller thread
/// after the dispatch drains, so a poisoned shard cannot wedge the pool.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

struct PoolShared {
    ctrl: Mutex<PoolCtrl>,
    /// Workers wait here for a published job (or shutdown).
    work: Condvar,
    /// The dispatching caller waits here for the last in-flight job.
    done: Condvar,
}

struct PoolCtrl {
    job: Option<JobPtr>,
    n_jobs: usize,
    next: usize,
    in_flight: usize,
    panicked: bool,
    shutdown: bool,
}

/// Type-erased pointer to the dispatch closure.  The pointee is only ever a
/// `&(dyn Fn(usize) + Sync)` borrowed by [`WorkerPool::run`], which does not
/// return until every claimed job has finished and the slot is cleared — so
/// the pointer never outlives its referent (scoped-thread-style reasoning).
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is Sync (shared calls are safe) and the lifetime is
// enforced by `run` joining before return, per the JobPtr doc above.
unsafe impl Send for JobPtr {}

impl WorkerPool {
    /// Spawn a pool with `threads` persistent workers (0 is valid: every
    /// dispatch then runs inline on the caller thread).
    pub fn new(threads: usize) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            ctrl: Mutex::new(PoolCtrl {
                job: None,
                n_jobs: 0,
                next: 0,
                in_flight: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..threads)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_body(&shared))
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Number of persistent worker threads (not counting the caller).
    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Run `f(0), f(1), …, f(n_jobs - 1)` across the workers and the caller
    /// thread; returns when all have finished.  Not reentrant (a job must
    /// not call `run` on the same pool).  Allocation-free once the pool is
    /// constructed.
    pub fn run(&self, n_jobs: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_jobs == 0 {
            return;
        }
        if self.handles.is_empty() {
            // Sequential fallback: identical claim order, no sync at all.
            for i in 0..n_jobs {
                f(i);
            }
            return;
        }
        {
            let mut g = self.shared.ctrl.lock().expect("pool lock");
            assert!(g.job.is_none(), "WorkerPool::run is not reentrant");
            g.job = Some(JobPtr(f as *const _));
            g.n_jobs = n_jobs;
            g.next = 0;
            g.in_flight = 0;
            g.panicked = false;
            self.shared.work.notify_all();
        }
        // The caller claims jobs too, then waits for stragglers.
        loop {
            let mut g = self.shared.ctrl.lock().expect("pool lock");
            if g.next < g.n_jobs {
                let i = g.next;
                g.next += 1;
                g.in_flight += 1;
                drop(g);
                let ok = catch_unwind(AssertUnwindSafe(|| f(i))).is_ok();
                let mut g = self.shared.ctrl.lock().expect("pool lock");
                g.in_flight -= 1;
                if !ok {
                    g.panicked = true;
                }
                if g.next >= g.n_jobs && g.in_flight == 0 {
                    self.shared.done.notify_all();
                }
                continue;
            }
            while !(g.next >= g.n_jobs && g.in_flight == 0) {
                g = self.shared.done.wait(g).expect("pool wait");
            }
            g.job = None;
            let panicked = g.panicked;
            drop(g);
            assert!(!panicked, "WorkerPool: a shard job panicked");
            return;
        }
    }
}

fn worker_body(shared: &PoolShared) {
    loop {
        let (job, i) = {
            let mut g = shared.ctrl.lock().expect("pool lock");
            loop {
                if g.shutdown {
                    return;
                }
                if let Some(job) = g.job {
                    if g.next < g.n_jobs {
                        let i = g.next;
                        g.next += 1;
                        g.in_flight += 1;
                        break (job, i);
                    }
                }
                g = shared.work.wait(g).expect("pool wait");
            }
        };
        // SAFETY: `run` has not returned (this job is in_flight), so the
        // closure behind the pointer is alive; it is Sync, so calling it
        // from this thread is safe.
        let f = unsafe { &*job.0 };
        let ok = catch_unwind(AssertUnwindSafe(|| f(i))).is_ok();
        let mut g = shared.ctrl.lock().expect("pool lock");
        g.in_flight -= 1;
        if !ok {
            g.panicked = true;
        }
        if g.next >= g.n_jobs && g.in_flight == 0 {
            shared.done.notify_all();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut g = self.shared.ctrl.lock().expect("pool lock");
            g.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Number of workers to use: respects `MALI_THREADS`, defaults to the
/// available parallelism (min 1).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("MALI_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// `map` over `items` with up to [`num_threads`] workers, preserving order.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.iter().map(&f).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for (ci, (items_chunk, out_chunk)) in items
            .chunks(chunk)
            .zip(out.chunks_mut(chunk))
            .enumerate()
        {
            let f = &f;
            let _ = ci;
            scope.spawn(move || {
                for (item, slot) in items_chunk.iter().zip(out_chunk.iter_mut()) {
                    *slot = Some(f(item));
                }
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Parallel for over index ranges (chunked), mutating disjoint slices.
///
/// In-flight threads are bounded by [`num_threads`]: the chunk list is
/// partitioned into at most that many contiguous groups, one scoped
/// thread each (a 100k-element call with tiny chunks must not spawn
/// thousands of threads).
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let workers = num_threads();
    if workers <= 1 || data.len() <= chunk {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let mut chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk).enumerate().collect();
    let n_chunks = chunks.len();
    let per_worker = n_chunks.div_ceil(workers.min(n_chunks));
    std::thread::scope(|scope| {
        for group in chunks.chunks_mut(per_worker) {
            let f = &f;
            scope.spawn(move || {
                for (i, c) in group.iter_mut() {
                    f(*i, c);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..97).collect();
        let out = par_map(&items, |&x| x * x);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i as u64) * (i as u64));
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(&empty, |&x| x).is_empty());
        assert_eq!(par_map(&[5u32], |&x| x + 1), vec![6]);
    }

    #[test]
    fn par_chunks_mut_touches_all() {
        let mut data = vec![0u32; 100];
        par_chunks_mut(&mut data, 7, |ci, chunk| {
            for x in chunk.iter_mut() {
                *x = ci as u32 + 1;
            }
        });
        assert!(data.iter().all(|&x| x > 0));
    }

    #[test]
    fn shard_ranges_cover_exactly() {
        for &(items, shards) in &[(10usize, 3usize), (7, 3), (3, 8), (0, 4), (5, 1), (16, 4)] {
            let ranges: Vec<_> = shard_ranges(items, shards).collect();
            assert_eq!(ranges.len(), shards.max(1));
            let mut cursor = 0usize;
            for &(s, e) in &ranges {
                assert_eq!(s, cursor, "contiguous ({items},{shards})");
                assert!(e >= s);
                cursor = e;
            }
            assert_eq!(cursor, items, "covering ({items},{shards})");
            // balanced: sizes differ by at most one, larger ones first
            let sizes: Vec<_> = ranges.iter().map(|&(s, e)| e - s).collect();
            for w in sizes.windows(2) {
                assert!(w[0] >= w[1] && w[0] - w[1] <= 1, "balanced {sizes:?}");
            }
        }
    }

    #[test]
    fn worker_pool_runs_every_job_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for threads in [0usize, 1, 3] {
            let pool = WorkerPool::new(threads);
            assert_eq!(pool.threads(), threads);
            let hits: Vec<AtomicUsize> = (0..23).map(|_| AtomicUsize::new(0)).collect();
            // reuse across dispatches: the same pool must stay healthy
            for _ in 0..3 {
                pool.run(hits.len(), &|i| {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                });
            }
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 3, "job {i} (threads {threads})");
            }
            pool.run(0, &|_| unreachable!("n_jobs = 0 dispatches nothing"));
        }
    }

    #[test]
    fn worker_pool_disjoint_rows_write_disjointly() {
        let pool = WorkerPool::new(2);
        let mut data = vec![0u32; 103];
        let n = data.len();
        let ranges: Vec<_> = shard_ranges(n, 5).collect();
        let view = DisjointRowsMut::new(&mut data);
        pool.run(ranges.len(), &|i| {
            let (s, e) = ranges[i];
            // SAFETY: each job index is claimed once; ranges are disjoint.
            let rows = unsafe { view.range(s, e) };
            for (j, x) in rows.iter_mut().enumerate() {
                *x = (s + j) as u32 + 1;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u32 + 1);
        }
    }

    /// Thousands of tiny chunks must not mean thousands of threads: the
    /// grouped dispatch handles a 100k-element / 6250-chunk call with at
    /// most `num_threads()` workers, visiting every chunk exactly once
    /// with its correct index.
    #[test]
    fn par_chunks_mut_bounds_thread_count() {
        let mut data = vec![0u64; 100_000];
        par_chunks_mut(&mut data, 16, |ci, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = (ci * 16 + j) as u64;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u64);
        }
    }
}
