//! Data-parallel helpers over std scoped threads.
//!
//! Neither tokio nor rayon is vendored in the offline image; training-time
//! parallelism here is simple fork-join over batch shards.  The PJRT CPU
//! client serializes device compute anyway, so the coordinator parallelizes
//! the host-side work (data synthesis, metric reduction, multi-seed runs)
//! and keeps device calls on the caller thread.

/// Number of workers to use: respects `MALI_THREADS`, defaults to the
/// available parallelism (min 1).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("MALI_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// `map` over `items` with up to [`num_threads`] workers, preserving order.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.iter().map(&f).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for (ci, (items_chunk, out_chunk)) in items
            .chunks(chunk)
            .zip(out.chunks_mut(chunk))
            .enumerate()
        {
            let f = &f;
            let _ = ci;
            scope.spawn(move || {
                for (item, slot) in items_chunk.iter().zip(out_chunk.iter_mut()) {
                    *slot = Some(f(item));
                }
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Parallel for over index ranges (chunked), mutating disjoint slices.
///
/// In-flight threads are bounded by [`num_threads`]: the chunk list is
/// partitioned into at most that many contiguous groups, one scoped
/// thread each (a 100k-element call with tiny chunks must not spawn
/// thousands of threads).
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let workers = num_threads();
    if workers <= 1 || data.len() <= chunk {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let mut chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk).enumerate().collect();
    let n_chunks = chunks.len();
    let per_worker = n_chunks.div_ceil(workers.min(n_chunks));
    std::thread::scope(|scope| {
        for group in chunks.chunks_mut(per_worker) {
            let f = &f;
            scope.spawn(move || {
                for (i, c) in group.iter_mut() {
                    f(*i, c);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..97).collect();
        let out = par_map(&items, |&x| x * x);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i as u64) * (i as u64));
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(&empty, |&x| x).is_empty());
        assert_eq!(par_map(&[5u32], |&x| x + 1), vec![6]);
    }

    #[test]
    fn par_chunks_mut_touches_all() {
        let mut data = vec![0u32; 100];
        par_chunks_mut(&mut data, 7, |ci, chunk| {
            for x in chunk.iter_mut() {
                *x = ci as u32 + 1;
            }
        });
        assert!(data.iter().all(|&x| x > 0));
    }

    /// Thousands of tiny chunks must not mean thousands of threads: the
    /// grouped dispatch handles a 100k-element / 6250-chunk call with at
    /// most `num_threads()` workers, visiting every chunk exactly once
    /// with its correct index.
    #[test]
    fn par_chunks_mut_bounds_thread_count() {
        let mut data = vec![0u64; 100_000];
        par_chunks_mut(&mut data, 16, |ci, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = (ci * 16 + j) as u64;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u64);
        }
    }
}
