//! Bench target regenerating paper Fig. 5 (see DESIGN.md §5).
//! Run with `cargo bench --bench fig5_cifar` (add `-- --full` for the
//! EXPERIMENTS.md scale).
use mali_ode::coordinator::{exp_images, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Quick };
    let t0 = std::time::Instant::now();
    let summary = exp_images::fig5(scale, 0).expect("fig5_cifar");
    mali_ode::coordinator::report::write_summary("runs", "fig5", &summary).expect("write summary");
    println!("\nfig5_cifar done in {:.1}s (runs/fig5.json written)", t0.elapsed().as_secs_f64());
}
