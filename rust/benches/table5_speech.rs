//! Bench target regenerating paper Table 5 (see DESIGN.md §5).
//! Run with `cargo bench --bench table5_speech` (add `-- --full` for the
//! EXPERIMENTS.md scale).
use mali_ode::coordinator::{exp_series, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Quick };
    let t0 = std::time::Instant::now();
    let summary = exp_series::table5(scale, 0).expect("table5_speech");
    mali_ode::coordinator::report::write_summary("runs", "table5", &summary).expect("write summary");
    println!("\ntable5_speech done in {:.1}s (runs/table5.json written)", t0.elapsed().as_secs_f64());
}
