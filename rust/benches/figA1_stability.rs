//! Bench target regenerating paper Appendix Fig. 1 (see DESIGN.md §5).
//! Run with `cargo bench --bench figA1_stability` (add `-- --full` for the
//! EXPERIMENTS.md scale).
use mali_ode::coordinator::{exp_toy, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Quick };
    let t0 = std::time::Instant::now();
    let summary = exp_toy::fig_a1(scale, 0).expect("figA1_stability");
    mali_ode::coordinator::report::write_summary("runs", "figA1", &summary).expect("write summary");
    println!("\nfigA1_stability done in {:.1}s (runs/figA1.json written)", t0.elapsed().as_secs_f64());
}
