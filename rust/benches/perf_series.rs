//! perf_series: throughput/memory baseline for the first-class
//! observation-grid path (the time-series workload shape).
//!
//! Measures MALI `grad_obs` row-steps/sec and tracked peak memory on the
//! toy problem with a per-observation square loss at
//! K ∈ {1, 8, 32} observations × B ∈ {1, 64} samples.  The acceptance
//! property on display: MALI's peak memory is **flat across K and the
//! step count** (one continuous ψ⁻¹ sweep with injections — no
//! per-segment checkpoints), so the K = 32 column costs the same bytes
//! as K = 1 while ACA-style per-segment checkpointing would scale with
//! the grid.
//!
//! Run: `cargo bench --bench perf_series` (append `-- --full` for longer
//! timing windows).

use mali_ode::grad::batch_driver::grad_obs_batched_pooled;
use mali_ode::grad::mali::Mali;
use mali_ode::grad::{IvpSpec, ObsGrid, ObsSquareLoss};
use mali_ode::solvers::alf::AlfSolver;
use mali_ode::solvers::batch::BatchSpec;
use mali_ode::solvers::dynamics::LinearToy;
use mali_ode::util::bench::{time_until, Table};
use mali_ode::util::mem::{fmt_bytes, MemTracker};
use mali_ode::util::pool;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let budget = if full { 2.0 } else { 0.3 };

    let n_z = 4usize;
    let (t_end, h) = (2.0, 0.02);
    let toy = LinearToy::new(-0.3, n_z);
    let solver = AlfSolver::new(1.0);
    let method = Mali;
    let spec = IvpSpec::fixed(0.0, t_end, h);
    // fixed-mode grid: ceil per segment, so the step count depends mildly
    // on K; measure it per configuration from the result stats
    println!(
        "perf_series: MALI grad_obs on the toy problem (n_z = {n_z}, h = {h}), {} worker threads",
        pool::num_threads()
    );
    let mut table = Table::new(
        "multi-observation MALI: steps/sec and tracked peak memory",
        &["B", "K", "row-steps/s", "peak mem", "f-evals"],
    );

    let mut peaks_by_k: Vec<(usize, usize, usize)> = Vec::new();
    for &bsz in &[1usize, 64] {
        for &k_obs in &[1usize, 8, 32] {
            let bspec = BatchSpec::new(bsz, n_z);
            let mut z0 = Vec::with_capacity(bspec.flat_len());
            for b in 0..bsz {
                let scale = 1.0 + 0.01 * b as f32;
                z0.extend([1.0 * scale, 0.5 * scale, -0.8 * scale, 1.5 * scale]);
            }
            let grid = ObsGrid::uniform(0.0, t_end, k_obs);
            let head = ObsSquareLoss {
                weights: vec![1.0; k_obs],
            };

            let tracker = MemTracker::new();
            let res = grad_obs_batched_pooled(
                &method,
                &toy,
                &solver,
                &spec,
                &grid,
                &z0,
                &bspec,
                &head,
                tracker.clone(),
            )
            .unwrap();
            let row_steps = res.stats.fwd.n_accepted as f64;
            let f_evals = res.stats.f_evals;
            let peak = tracker.peak_bytes();
            peaks_by_k.push((bsz, k_obs, peak));

            let t = time_until(budget, || {
                let _ = grad_obs_batched_pooled(
                    &method,
                    &toy,
                    &solver,
                    &spec,
                    &grid,
                    &z0,
                    &bspec,
                    &head,
                    MemTracker::new(),
                )
                .unwrap();
            });
            table.row(&[
                bsz.to_string(),
                k_obs.to_string(),
                format!("{:.0}", row_steps / t.mean_s),
                fmt_bytes(peak),
                f_evals.to_string(),
            ]);
        }
    }
    table.print();

    // the law on display: per-B, the peak is identical across K
    for &bsz in &[1usize, 64] {
        let peaks: Vec<usize> = peaks_by_k
            .iter()
            .filter(|(b, _, _)| *b == bsz)
            .map(|&(_, _, p)| p)
            .collect();
        let flat = peaks.windows(2).all(|w| w[0] == w[1]);
        println!(
            "B={bsz}: MALI peak across K in {{1, 8, 32}} = {:?} — {}",
            peaks,
            if flat { "FLAT (constant-memory law holds)" } else { "NOT FLAT (regression!)" }
        );
    }
}
