//! Bench target for E12 — the online-serving latency/throughput grid
//! (see DESIGN.md §5/§10): dynamic micro-batching (plus intra-batch
//! sharding at shards ∈ {2, 4}) vs solo vs naive
//! one-request-one-integration, fixed and adaptive stepping.
//! Run with `cargo bench --bench perf_serve` (add `-- --full` for the
//! EXPERIMENTS.md scale); `runs/serve.json` is the artifact CI uploads
//! next to `BENCH_hotpath.json`.
use mali_ode::coordinator::{exp_serve, report, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Quick };
    let t0 = std::time::Instant::now();
    let summary = exp_serve::serve_bench(scale, 0).expect("perf_serve");
    report::write_summary("runs", "serve", &summary).expect("write summary");
    println!(
        "\nperf_serve done in {:.1}s (runs/serve.json written)",
        t0.elapsed().as_secs_f64()
    );
}
