//! Bench target regenerating paper Fig. 6 (see DESIGN.md §5).
//! Run with `cargo bench --bench fig6_imagenet` (add `-- --full` for the
//! EXPERIMENTS.md scale).
use mali_ode::coordinator::{exp_images, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Quick };
    let t0 = std::time::Instant::now();
    let summary = exp_images::fig6(scale, 0).expect("fig6_imagenet");
    mali_ode::coordinator::report::write_summary("runs", "fig6", &summary).expect("write summary");
    println!("\nfig6_imagenet done in {:.1}s (runs/fig6.json written)", t0.elapsed().as_secs_f64());
}
