//! Bench target regenerating paper Table 2 (see DESIGN.md §5).
//! Run with `cargo bench --bench table2_invariance` (add `-- --full` for the
//! EXPERIMENTS.md scale).
use mali_ode::coordinator::{exp_images, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Quick };
    let t0 = std::time::Instant::now();
    let summary = exp_images::table2(scale, 0).expect("table2_invariance");
    mali_ode::coordinator::report::write_summary("runs", "table2", &summary).expect("write summary");
    println!("\ntable2_invariance done in {:.1}s (runs/table2.json written)", t0.elapsed().as_secs_f64());
}
