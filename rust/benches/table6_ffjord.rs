//! Bench target regenerating paper Table 6 (see DESIGN.md §5).
//! Run with `cargo bench --bench table6_ffjord` (add `-- --full` for the
//! EXPERIMENTS.md scale).
use mali_ode::coordinator::{exp_flows, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Quick };
    let t0 = std::time::Instant::now();
    let summary = exp_flows::table6(scale, 0).expect("table6_ffjord");
    mali_ode::coordinator::report::write_summary("runs", "table6", &summary).expect("write summary");
    println!("\ntable6_ffjord done in {:.1}s (runs/table6.json written)", t0.elapsed().as_secs_f64());
}
