//! Bench target regenerating paper Fig. 4 (a,b,c) (see DESIGN.md §5).
//! Run with `cargo bench --bench fig4_toy` (add `-- --full` for the
//! EXPERIMENTS.md scale).
use mali_ode::coordinator::{exp_toy, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Quick };
    let t0 = std::time::Instant::now();
    let summary = exp_toy::fig4(scale, 0).expect("fig4_toy");
    mali_ode::coordinator::report::write_summary("runs", "fig4", &summary).expect("write summary");
    println!("\nfig4_toy done in {:.1}s (runs/fig4.json written)", t0.elapsed().as_secs_f64());
}
