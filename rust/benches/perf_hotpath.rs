//! perf_hotpath: solver/grad hot-path throughput + allocation pressure,
//! with a JSON emitter seeding the repo's recorded bench trajectory
//! (`BENCH_hotpath.json` at the repository root).
//!
//! The zero-allocation refactor's claim is that steps/sec on small-`N_z`
//! models is bounded by the allocator, not the FLOPs.  This bench pins
//! that empirically, per configuration:
//!
//! * **kernel A/B** — the MALI round trip (N fixed ALF steps forward +
//!   the full ψ⁻¹ reverse sweep) driven once through the *allocating*
//!   `step`/`invert_and_vjp` entry points and once through the
//!   workspace `step_into`/`invert_and_vjp_into` path.  Identical
//!   arithmetic (the wrappers delegate to the `_into` kernels), so the
//!   ratio isolates pure allocator cost; the acceptance bar is ≥ 2× on
//!   the small-`N_z` solo fixed-grid config.
//! * **end-to-end grads** — steps/sec, heap allocations/step and heap
//!   bytes/step (via a counting global allocator) for
//!   solo/batch × fixed/adaptive × all four gradient methods on the E1
//!   toy dynamics.
//!
//! Run: `cargo bench --bench perf_hotpath` (append `-- --smoke` for the
//! short CI windows; `MALI_BENCH_OUT` overrides the JSON path).

use mali_ode::grad::{by_name as grad_by_name, IvpSpec, SquareLoss};
use mali_ode::solvers::batch::BatchSpec;
use mali_ode::solvers::by_name as solver_by_name;
use mali_ode::solvers::dynamics::LinearToy;
use mali_ode::solvers::workspace::SolverWorkspace;
use mali_ode::solvers::{Solver, State};
use mali_ode::util::bench::{time_until, Table};
use mali_ode::util::json::Json;
use mali_ode::util::mem::MemTracker;
// The counting allocator (calls + bytes) is shared with the
// tests/alloc_*.rs binaries so the counting rules cannot diverge.
#[path = "../tests/common/counting_alloc.rs"]
mod counting_alloc;
use counting_alloc::{alloc_snapshot, CountingAlloc};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// MALI round trip through the *allocating* entry points: N fixed ALF
/// steps forward, then the ψ⁻¹ + vjp reverse sweep.
fn roundtrip_alloc(solver: &dyn Solver, toy: &LinearToy, z0: &[f32], h: f64, n: usize) -> f32 {
    let mut state = solver.init(toy, 0.0, z0);
    for i in 0..n {
        let (next, _err) = solver.step(toy, i as f64 * h, h, &state);
        state = next;
    }
    let mut a = State {
        z: state.z.iter().map(|&z| 2.0 * z).collect(),
        v: Some(vec![0.0f32; state.z.len()]),
    };
    let mut grad_theta = vec![0.0f32; 1];
    let mut cur = state;
    for i in (1..=n).rev() {
        let (prev, a_prev, dth) = solver
            .invert_and_vjp(toy, i as f64 * h, h, &cur, &a)
            .expect("ALF is invertible");
        mali_ode::tensor::axpy(1.0, &dth, &mut grad_theta);
        cur = prev;
        a = a_prev;
    }
    grad_theta[0] + a.z[0]
}

/// The same round trip through the workspace path: preallocated states,
/// `step_into` / `invert_and_vjp_into`, zero steady-state allocations.
#[allow(clippy::too_many_arguments)]
fn roundtrip_ws(
    solver: &dyn Solver,
    toy: &LinearToy,
    z0: &[f32],
    h: f64,
    n: usize,
    ws: &mut SolverWorkspace,
    bufs: &mut [State; 4],
) -> f32 {
    let [state, next, prev, a_prev] = bufs;
    *state = solver.init(toy, 0.0, z0);
    let mut err = Vec::new();
    for i in 0..n {
        solver.step_into(toy, i as f64 * h, h, state, next, &mut err, ws);
        std::mem::swap(state, next);
    }
    let mut a = State {
        z: state.z.iter().map(|&z| 2.0 * z).collect(),
        v: Some(vec![0.0f32; state.z.len()]),
    };
    let mut grad_theta = vec![0.0f32; 1];
    for i in (1..=n).rev() {
        let ok = solver.invert_and_vjp_into(
            toy,
            i as f64 * h,
            h,
            state,
            &a,
            prev,
            a_prev,
            &mut grad_theta,
            ws,
        );
        assert!(ok, "ALF is invertible");
        std::mem::swap(state, prev);
        std::mem::swap(&mut a, a_prev);
    }
    grad_theta[0] + a.z[0]
}

/// Measure one end-to-end gradient configuration: accepted steps/sec,
/// heap allocations/step and heap bytes/step (one protocol for solo and
/// batch, so the recorded JSON stays comparable across configs).
fn measure_config(
    name: String,
    budget: f64,
    table: &mut Table,
    configs: &mut Vec<(String, Json)>,
    mut run: impl FnMut() -> usize,
) {
    let steps = run().max(1) as f64;
    let t = time_until(budget, || {
        std::hint::black_box(run());
    });
    let before = alloc_snapshot();
    run();
    let after = alloc_snapshot();
    let sps = steps / t.min_s;
    let aps = (after.0 - before.0) as f64 / steps;
    let bps = (after.1 - before.1) as f64 / steps;
    table.row(&[
        name.clone(),
        format!("{sps:.0}"),
        format!("{aps:.1}"),
        format!("{bps:.0}"),
    ]);
    configs.push((
        name,
        Json::obj(vec![
            ("steps_per_sec", Json::Num(sps)),
            ("allocs_per_step", Json::Num(aps)),
            ("bytes_per_step", Json::Num(bps)),
            ("accepted_steps", Json::Num(steps)),
        ]),
    ));
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let budget = if smoke { 0.15 } else { 0.8 };
    let mut root = Json::Obj(Default::default());
    let mut configs: Vec<(String, Json)> = Vec::new();
    let mut table = Table::new(
        "perf_hotpath: throughput and allocation pressure",
        &["config", "steps/s", "allocs/step", "bytes/step"],
    );

    // ---- kernel A/B: allocating vs workspace MALI round trip ------------
    let mut speedups: Vec<(String, Json)> = Vec::new();
    for &(label, n_z) in &[("n_z=4", 4usize), ("n_z=64", 64usize)] {
        let toy = LinearToy::new(-0.3, n_z);
        let solver = solver_by_name("alf").unwrap();
        let z0: Vec<f32> = (0..n_z).map(|i| 1.0 + 0.01 * i as f32).collect();
        let (h, n) = (0.02, 250usize);

        let t_alloc = time_until(budget, || {
            std::hint::black_box(roundtrip_alloc(&*solver, &toy, &z0, h, n));
        });
        let mut ws = SolverWorkspace::new();
        let mut bufs = [
            State { z: Vec::new(), v: None },
            State { z: Vec::new(), v: None },
            State { z: Vec::new(), v: None },
            State { z: Vec::new(), v: None },
        ];
        let t_ws = time_until(budget, || {
            std::hint::black_box(roundtrip_ws(&*solver, &toy, &z0, h, n, &mut ws, &mut bufs));
        });
        // allocation counts for one workspace round trip (steady state)
        roundtrip_ws(&*solver, &toy, &z0, h, n, &mut ws, &mut bufs);
        let before = alloc_snapshot();
        roundtrip_ws(&*solver, &toy, &z0, h, n, &mut ws, &mut bufs);
        let after = alloc_snapshot();

        // 2n micro-steps per round trip (n forward + n reverse)
        let steps = 2.0 * n as f64;
        let sps_alloc = steps / t_alloc.min_s;
        let sps_ws = steps / t_ws.min_s;
        let speedup = sps_ws / sps_alloc;
        table.row(&[
            format!("kernel.{label}.alloc"),
            format!("{sps_alloc:.0}"),
            "-".into(),
            "-".into(),
        ]);
        table.row(&[
            format!("kernel.{label}.ws"),
            format!("{sps_ws:.0}"),
            format!("{:.2}", (after.0 - before.0) as f64 / steps),
            format!("{:.1}", (after.1 - before.1) as f64 / steps),
        ]);
        println!("kernel {label}: workspace vs allocating speedup = {speedup:.2}x");
        speedups.push((
            label.to_string(),
            Json::obj(vec![
                ("steps_per_sec_alloc", Json::Num(sps_alloc)),
                ("steps_per_sec_ws", Json::Num(sps_ws)),
                ("speedup_ws_vs_alloc", Json::Num(speedup)),
                (
                    "ws_allocs_per_step",
                    Json::Num((after.0 - before.0) as f64 / steps),
                ),
                (
                    "ws_bytes_per_step",
                    Json::Num((after.1 - before.1) as f64 / steps),
                ),
            ]),
        ));
    }

    // ---- end-to-end gradient configurations -----------------------------
    let n_z = 4usize;
    let batch = 32usize;
    let t_end = 2.0;
    for &(mode_label, fixed) in &[("fixed", true), ("adaptive", false)] {
        for method_name in ["mali", "aca", "naive", "adjoint"] {
            let method = grad_by_name(method_name).unwrap();
            let solver = if method_name == "adjoint" {
                solver_by_name("heun-euler").unwrap()
            } else {
                solver_by_name("alf").unwrap()
            };
            let spec = if fixed {
                IvpSpec::fixed(0.0, t_end, 0.02)
            } else {
                IvpSpec::adaptive(0.0, t_end, 1e-4, 1e-6)
            };

            // solo
            let toy = LinearToy::new(-0.3, n_z);
            let z0: Vec<f32> = (0..n_z).map(|i| 1.0 + 0.01 * i as f32).collect();
            measure_config(
                format!("solo.{mode_label}.{method_name}"),
                budget,
                &mut table,
                &mut configs,
                || {
                    method
                        .grad(&toy, &*solver, &spec, &z0, &SquareLoss, MemTracker::new())
                        .unwrap()
                        .stats
                        .fwd
                        .n_accepted
                },
            );

            // batch (row-steps/sec; one grad_batch call)
            let bspec = BatchSpec::new(batch, n_z);
            let mut z0b = Vec::with_capacity(bspec.flat_len());
            for b in 0..batch {
                let scale = 1.0 + 0.005 * b as f32;
                z0b.extend((0..n_z).map(|i| scale * (1.0 + 0.01 * i as f32)));
            }
            measure_config(
                format!("batch{batch}.{mode_label}.{method_name}"),
                budget,
                &mut table,
                &mut configs,
                || {
                    method
                        .grad_batch(
                            &toy,
                            &*solver,
                            &spec,
                            &z0b,
                            &bspec,
                            &SquareLoss,
                            MemTracker::new(),
                        )
                        .unwrap()
                        .stats
                        .fwd
                        .n_accepted
                },
            );
        }
    }

    table.print();

    // ---- JSON emission ---------------------------------------------------
    if let Json::Obj(map) = &mut root {
        map.insert("bench".into(), Json::Str("perf_hotpath".into()));
        map.insert(
            "provenance".into(),
            Json::Str(if smoke { "measured-smoke" } else { "measured" }.into()),
        );
        map.insert(
            "kernel".into(),
            Json::Obj(speedups.into_iter().collect()),
        );
        map.insert(
            "configs".into(),
            Json::Obj(configs.into_iter().collect()),
        );
    }
    let out_path = std::env::var("MALI_BENCH_OUT")
        .unwrap_or_else(|_| "../BENCH_hotpath.json".to_string());
    match std::fs::write(&out_path, root.pretty() + "\n") {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
