//! perf_hotpath: solver/grad hot-path throughput + allocation pressure,
//! with a JSON emitter seeding the repo's recorded bench trajectory
//! (`BENCH_hotpath.json` at the repository root).
//!
//! The zero-allocation refactor's claim is that steps/sec on small-`N_z`
//! models is bounded by the allocator, not the FLOPs.  This bench pins
//! that empirically, per configuration:
//!
//! * **kernel A/B** — the MALI round trip (N fixed ALF steps forward +
//!   the full ψ⁻¹ reverse sweep) driven once through the *allocating*
//!   `step`/`invert_and_vjp` entry points and once through the
//!   workspace `step_into`/`invert_and_vjp_into` path.  Identical
//!   arithmetic (the wrappers delegate to the `_into` kernels), so the
//!   ratio isolates pure allocator cost; the acceptance bar is ≥ 2× on
//!   the small-`N_z` solo fixed-grid config.  A third row runs the same
//!   workspace round trip on the reversible-4 composition (three ψ
//!   sub-steps, 3 f-evals per step, 4th order), recording what the
//!   higher order costs per step at the same step count.
//! * **tensor kernels** — elements/sec for the flat-buffer kernels
//!   (`axpy_rows`, `add_scaled_rows_into`, `lincomb_into`,
//!   `matmul_into`) through the chunked dispatch path vs the frozen
//!   `tensor::scalar` oracle, at `n_z ∈ {4, 64}`; the JSON records
//!   whether the build had the `simd` feature (`simd_feature`) so rows
//!   from different builds are never compared blind.
//! * **native MLP fused dispatch** — steps/sec of the MALI round trip
//!   over `dynamics_native::MlpDynamics` at hidden ∈ {64, 256} with the
//!   fused ψ/ψ⁻¹/ψ-vjp entries vs the composed unfused kernels
//!   (bitwise-identical arithmetic, `tests/prop_solver.rs` pins it), and
//!   a dispatch-vs-scalar `matmul_into` A/B at the same hidden widths.
//! * **intra-batch sharding** — row-steps/sec of the sharded batched
//!   integrator (`integrate_batch_obs_stats_sharded`) at
//!   shards ∈ {1, 2, 4} on a persistent `WorkerPool`, `n_z ∈ {4, 64}`,
//!   with the speedup over the 1-shard run.
//! * **end-to-end grads** — steps/sec, heap allocations/step and heap
//!   bytes/step (via a counting global allocator) for
//!   solo/batch × fixed/adaptive × all five gradient protocols on the
//!   E1 toy dynamics.
//!
//! Run: `cargo bench --bench perf_hotpath` (append `-- --smoke` for the
//! short CI windows; `MALI_BENCH_OUT` overrides the JSON path).

use mali_ode::dynamics_native::{MlpDynamics as NativeMlp, TimeMode};
use mali_ode::grad::{by_name as grad_by_name, IvpSpec, SquareLoss};
use mali_ode::solvers::alf::AlfSolver;
use mali_ode::solvers::batch::{BatchSpec, BatchState};
use mali_ode::solvers::by_name as solver_by_name;
use mali_ode::solvers::dynamics::{Dynamics, LinearToy};
use mali_ode::solvers::integrate::{
    integrate_batch_obs_stats_sharded, BatchShards, ErrorNorm, ObsGrid, StepMode,
};
use mali_ode::solvers::workspace::{BatchWorkspace, SolverWorkspace};
use mali_ode::solvers::{Solver, State};
use mali_ode::tensor;
use mali_ode::util::bench::{time_until, Table};
use mali_ode::util::json::Json;
use mali_ode::util::mem::MemTracker;
use mali_ode::util::pool::WorkerPool;
use mali_ode::util::rng::Rng;
// The counting allocator (calls + bytes) is shared with the
// tests/alloc_*.rs binaries so the counting rules cannot diverge.
#[path = "../tests/common/counting_alloc.rs"]
mod counting_alloc;
use counting_alloc::{alloc_snapshot, CountingAlloc};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// MALI round trip through the *allocating* entry points: N fixed ALF
/// steps forward, then the ψ⁻¹ + vjp reverse sweep.
fn roundtrip_alloc(solver: &dyn Solver, toy: &LinearToy, z0: &[f32], h: f64, n: usize) -> f32 {
    let mut state = solver.init(toy, 0.0, z0);
    for i in 0..n {
        let (next, _err) = solver.step(toy, i as f64 * h, h, &state);
        state = next;
    }
    let mut a = State {
        z: state.z.iter().map(|&z| 2.0 * z).collect(),
        v: Some(vec![0.0f32; state.z.len()]),
    };
    let mut grad_theta = vec![0.0f32; 1];
    let mut cur = state;
    for i in (1..=n).rev() {
        let (prev, a_prev, dth) = solver
            .invert_and_vjp(toy, i as f64 * h, h, &cur, &a)
            .expect("ALF is invertible");
        mali_ode::tensor::axpy(1.0, &dth, &mut grad_theta);
        cur = prev;
        a = a_prev;
    }
    grad_theta[0] + a.z[0]
}

/// The same round trip through the workspace path: preallocated states,
/// `step_into` / `invert_and_vjp_into`, zero steady-state allocations.
#[allow(clippy::too_many_arguments)]
fn roundtrip_ws(
    solver: &dyn Solver,
    toy: &LinearToy,
    z0: &[f32],
    h: f64,
    n: usize,
    ws: &mut SolverWorkspace,
    bufs: &mut [State; 4],
) -> f32 {
    let [state, next, prev, a_prev] = bufs;
    *state = solver.init(toy, 0.0, z0);
    let mut err = Vec::new();
    for i in 0..n {
        solver.step_into(toy, i as f64 * h, h, state, next, &mut err, ws);
        std::mem::swap(state, next);
    }
    let mut a = State {
        z: state.z.iter().map(|&z| 2.0 * z).collect(),
        v: Some(vec![0.0f32; state.z.len()]),
    };
    let mut grad_theta = vec![0.0f32; 1];
    for i in (1..=n).rev() {
        let ok = solver.invert_and_vjp_into(
            toy,
            i as f64 * h,
            h,
            state,
            &a,
            prev,
            a_prev,
            &mut grad_theta,
            ws,
        );
        assert!(ok, "ALF is invertible");
        std::mem::swap(state, prev);
        std::mem::swap(&mut a, a_prev);
    }
    grad_theta[0] + a.z[0]
}

/// The MALI round trip over an arbitrary `Dynamics` through the
/// workspace path — like [`roundtrip_ws`], but with a caller-sized
/// θ-gradient buffer so it works for multi-parameter models.
#[allow(clippy::too_many_arguments)]
fn native_roundtrip(
    solver: &dyn Solver,
    dynamics: &dyn Dynamics,
    z0: &[f32],
    h: f64,
    n: usize,
    ws: &mut SolverWorkspace,
    bufs: &mut [State; 4],
    grad_theta: &mut [f32],
) -> f32 {
    let [state, next, prev, a_prev] = bufs;
    *state = solver.init(dynamics, 0.0, z0);
    let mut err = Vec::new();
    for i in 0..n {
        solver.step_into(dynamics, i as f64 * h, h, state, next, &mut err, ws);
        std::mem::swap(state, next);
    }
    let mut a = State {
        z: state.z.iter().map(|&z| 2.0 * z).collect(),
        v: Some(vec![0.0f32; state.z.len()]),
    };
    for i in (1..=n).rev() {
        let ok = solver.invert_and_vjp_into(
            dynamics,
            i as f64 * h,
            h,
            state,
            &a,
            prev,
            a_prev,
            grad_theta,
            ws,
        );
        assert!(ok, "ALF is invertible");
        std::mem::swap(state, prev);
        std::mem::swap(&mut a, a_prev);
    }
    grad_theta[0] + a.z[0]
}

/// Measure one end-to-end gradient configuration: accepted steps/sec,
/// heap allocations/step and heap bytes/step (one protocol for solo and
/// batch, so the recorded JSON stays comparable across configs).
fn measure_config(
    name: String,
    budget: f64,
    table: &mut Table,
    configs: &mut Vec<(String, Json)>,
    mut run: impl FnMut() -> usize,
) {
    let steps = run().max(1) as f64;
    let t = time_until(budget, || {
        std::hint::black_box(run());
    });
    let before = alloc_snapshot();
    run();
    let after = alloc_snapshot();
    let sps = steps / t.min_s;
    let aps = (after.0 - before.0) as f64 / steps;
    let bps = (after.1 - before.1) as f64 / steps;
    table.row(&[
        name.clone(),
        format!("{sps:.0}"),
        format!("{aps:.1}"),
        format!("{bps:.0}"),
    ]);
    configs.push((
        name,
        Json::obj(vec![
            ("steps_per_sec", Json::Num(sps)),
            ("allocs_per_step", Json::Num(aps)),
            ("bytes_per_step", Json::Num(bps)),
            ("accepted_steps", Json::Num(steps)),
        ]),
    ));
}

/// Time two closures (scalar oracle vs dispatch kernel) and convert to
/// elements/sec; returns `(scalar_per_sec, dispatch_per_sec)`.
fn ab_throughput(
    budget: f64,
    elems: f64,
    scalar: impl FnMut(),
    dispatch: impl FnMut(),
) -> (f64, f64) {
    let ts = time_until(budget, scalar);
    let td = time_until(budget, dispatch);
    (elems / ts.min_s, elems / td.min_s)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let budget = if smoke { 0.15 } else { 0.8 };
    let mut root = Json::Obj(Default::default());
    let mut configs: Vec<(String, Json)> = Vec::new();
    let mut table = Table::new(
        "perf_hotpath: throughput and allocation pressure",
        &["config", "steps/s", "allocs/step", "bytes/step"],
    );

    // ---- kernel A/B: allocating vs workspace MALI round trip ------------
    let mut speedups: Vec<(String, Json)> = Vec::new();
    for &(label, n_z) in &[("n_z=4", 4usize), ("n_z=64", 64usize)] {
        let toy = LinearToy::new(-0.3, n_z);
        let solver = solver_by_name("alf").unwrap();
        let z0: Vec<f32> = (0..n_z).map(|i| 1.0 + 0.01 * i as f32).collect();
        let (h, n) = (0.02, 250usize);

        let t_alloc = time_until(budget, || {
            std::hint::black_box(roundtrip_alloc(&*solver, &toy, &z0, h, n));
        });
        let mut ws = SolverWorkspace::new();
        let mut bufs = [
            State { z: Vec::new(), v: None },
            State { z: Vec::new(), v: None },
            State { z: Vec::new(), v: None },
            State { z: Vec::new(), v: None },
        ];
        let t_ws = time_until(budget, || {
            std::hint::black_box(roundtrip_ws(&*solver, &toy, &z0, h, n, &mut ws, &mut bufs));
        });
        // allocation counts for one workspace round trip (steady state)
        roundtrip_ws(&*solver, &toy, &z0, h, n, &mut ws, &mut bufs);
        let before = alloc_snapshot();
        roundtrip_ws(&*solver, &toy, &z0, h, n, &mut ws, &mut bufs);
        let after = alloc_snapshot();

        // reversible-4 on the same workspace round trip: what 4th order
        // (three chained ψ sub-steps) costs per step vs plain ALF
        let rev4 = solver_by_name("reversible4").unwrap();
        let mut ws_r = SolverWorkspace::new();
        let mut bufs_r = [
            State { z: Vec::new(), v: None },
            State { z: Vec::new(), v: None },
            State { z: Vec::new(), v: None },
            State { z: Vec::new(), v: None },
        ];
        let t_rev4 = time_until(budget, || {
            std::hint::black_box(roundtrip_ws(&*rev4, &toy, &z0, h, n, &mut ws_r, &mut bufs_r));
        });

        // 2n micro-steps per round trip (n forward + n reverse)
        let steps = 2.0 * n as f64;
        let sps_alloc = steps / t_alloc.min_s;
        let sps_ws = steps / t_ws.min_s;
        let sps_rev4 = steps / t_rev4.min_s;
        let speedup = sps_ws / sps_alloc;
        let alf_vs_rev4 = sps_ws / sps_rev4;
        table.row(&[
            format!("kernel.{label}.alloc"),
            format!("{sps_alloc:.0}"),
            "-".into(),
            "-".into(),
        ]);
        table.row(&[
            format!("kernel.{label}.ws"),
            format!("{sps_ws:.0}"),
            format!("{:.2}", (after.0 - before.0) as f64 / steps),
            format!("{:.1}", (after.1 - before.1) as f64 / steps),
        ]);
        table.row(&[
            format!("kernel.{label}.rev4_ws"),
            format!("{sps_rev4:.0}"),
            "-".into(),
            "-".into(),
        ]);
        println!("kernel {label}: workspace vs allocating speedup = {speedup:.2}x");
        println!(
            "kernel {label}: reversible-4 {sps_rev4:.0} steps/s \
             (ALF is {alf_vs_rev4:.2}x faster per step at the same grid)"
        );
        speedups.push((
            label.to_string(),
            Json::obj(vec![
                ("steps_per_sec_alloc", Json::Num(sps_alloc)),
                ("steps_per_sec_ws", Json::Num(sps_ws)),
                ("speedup_ws_vs_alloc", Json::Num(speedup)),
                ("steps_per_sec_rev4_ws", Json::Num(sps_rev4)),
                ("alf_vs_rev4_ws", Json::Num(alf_vs_rev4)),
                (
                    "ws_allocs_per_step",
                    Json::Num((after.0 - before.0) as f64 / steps),
                ),
                (
                    "ws_bytes_per_step",
                    Json::Num((after.1 - before.1) as f64 / steps),
                ),
            ]),
        ));
    }

    // ---- tensor kernels: chunked/SIMD dispatch vs scalar oracle ---------
    // Same arithmetic by the bitwise contract (tests/prop_kernels.rs);
    // this measures what the dispatch layer buys.  Units: elements/sec
    // for the elementwise kernels, multiply-accumulates/sec for matmul.
    let simd_on = if tensor::simd_enabled() { "on" } else { "off" };
    let mut tensor_rows: Vec<(String, Json)> = Vec::new();
    for &(label, n_z) in &[("n_z=4", 4usize), ("n_z=64", 64usize)] {
        let b = 32usize;
        let flat = b * n_z;
        let reps = 32usize;
        let mut rng = Rng::new(42);
        let mut fill = |n: usize, lo: f64, hi: f64| -> Vec<f32> {
            (0..n).map(|_| rng.range(lo, hi) as f32).collect()
        };
        let x = fill(flat, -1.0, 1.0);
        let w1 = fill(flat, -1.0, 1.0);
        let w2 = fill(flat, -1.0, 1.0);
        let w3 = fill(flat, -1.0, 1.0);
        // tiny coefficients keep the accumulating axpy buffers bounded
        // over the many timed repetitions
        let coeffs = fill(b, -1e-4, 1e-4);
        let bmat = fill(n_z * n_z, -1.0, 1.0);
        let mut ys = x.clone();
        let mut yd = x.clone();
        let mut out_s = vec![0.0f32; flat];
        let mut out_d = vec![0.0f32; flat];
        let mut mm_s = vec![0.0f32; flat];
        let mut mm_d = vec![0.0f32; flat];
        let terms = [
            (0.3f32, x.as_slice()),
            (0.25f32, w1.as_slice()),
            (-0.5f32, w2.as_slice()),
            (1.0f32, w3.as_slice()),
        ];

        let mut kernels: Vec<(String, Json)> = Vec::new();
        let record = |name: &str, sc: f64, di: f64, kernels: &mut Vec<(String, Json)>| {
            println!(
                "tensor {label} {name}: scalar {sc:.3e}/s dispatch {di:.3e}/s \
                 ({:.2}x, simd {simd_on})",
                di / sc
            );
            kernels.push((
                name.to_string(),
                Json::obj(vec![
                    ("scalar_per_sec", Json::Num(sc)),
                    ("dispatch_per_sec", Json::Num(di)),
                    ("speedup_dispatch_vs_scalar", Json::Num(di / sc)),
                ]),
            ));
        };

        let (sc, di) = ab_throughput(
            budget,
            (reps * flat) as f64,
            || {
                for _ in 0..reps {
                    tensor::scalar::axpy_rows(&coeffs, &x, &mut ys, n_z);
                }
            },
            || {
                for _ in 0..reps {
                    tensor::axpy_rows(&coeffs, &x, &mut yd, n_z);
                }
            },
        );
        record("axpy_rows", sc, di, &mut kernels);

        let (sc, di) = ab_throughput(
            budget,
            (reps * flat) as f64,
            || {
                for _ in 0..reps {
                    tensor::scalar::add_scaled_rows_into(&x, &coeffs, &w1, n_z, &mut out_s);
                }
            },
            || {
                for _ in 0..reps {
                    tensor::add_scaled_rows_into(&x, &coeffs, &w1, n_z, &mut out_d);
                }
            },
        );
        record("add_scaled_rows_into", sc, di, &mut kernels);

        let (sc, di) = ab_throughput(
            budget,
            (reps * flat) as f64,
            || {
                for _ in 0..reps {
                    tensor::scalar::lincomb_into(&terms, &mut out_s);
                }
            },
            || {
                for _ in 0..reps {
                    tensor::lincomb_into(&terms, &mut out_d);
                }
            },
        );
        record("lincomb_into", sc, di, &mut kernels);

        let (sc, di) = ab_throughput(
            budget,
            (reps * b * n_z * n_z) as f64,
            || {
                for _ in 0..reps {
                    tensor::scalar::matmul_into(&x, &bmat, b, n_z, n_z, &mut mm_s);
                }
            },
            || {
                for _ in 0..reps {
                    tensor::matmul_into(&x, &bmat, b, n_z, n_z, &mut mm_d);
                }
            },
        );
        record("matmul_into", sc, di, &mut kernels);

        std::hint::black_box((&ys, &yd, &out_s, &out_d, &mm_s, &mm_d));
        tensor_rows.push((label.to_string(), Json::Obj(kernels.into_iter().collect())));
    }

    // ---- native MLP: fused vs unfused ψ dispatch ------------------------
    // Bitwise the same numbers either way (tests/prop_solver.rs); the
    // ratio measures what one-dispatch-per-ψ-step buys once a real layer
    // stack, not a toy, sits under the solver.
    let mut mlp_rows: Vec<(String, Json)> = Vec::new();
    for &(label, hidden) in &[("hidden=64", 64usize), ("hidden=256", 256usize)] {
        let n_z = 16usize;
        let mut rng = Rng::new(7);
        let mlp = NativeMlp::new(n_z, &[hidden], TimeMode::Concat, &mut rng);
        let fused = AlfSolver::new(1.0);
        assert!(fused.prefer_fused);
        let unfused = AlfSolver {
            eta: 1.0,
            prefer_fused: false,
        };
        let z0: Vec<f32> = (0..n_z).map(|i| 0.5 + 0.01 * i as f32).collect();
        let (h, n) = (0.05, 40usize);
        let steps = 2.0 * n as f64;
        let mut ws = SolverWorkspace::new();
        let mut bufs = [
            State { z: Vec::new(), v: None },
            State { z: Vec::new(), v: None },
            State { z: Vec::new(), v: None },
            State { z: Vec::new(), v: None },
        ];
        let mut grad_theta = vec![0.0f32; mlp.param_dim()];
        let mut measure = |solver: &AlfSolver| -> f64 {
            let t = time_until(budget, || {
                grad_theta.fill(0.0);
                std::hint::black_box(native_roundtrip(
                    solver,
                    &mlp,
                    &z0,
                    h,
                    n,
                    &mut ws,
                    &mut bufs,
                    &mut grad_theta,
                ));
            });
            steps / t.min_s
        };
        let sps_fused = measure(&fused);
        let sps_unfused = measure(&unfused);
        let speedup = sps_fused / sps_unfused;
        println!(
            "mlp {label}: fused {sps_fused:.3e} steps/s, unfused {sps_unfused:.3e} \
             ({speedup:.2}x)"
        );

        // dispatch vs scalar matmul at this hidden width — the kernel
        // the fused step spends its time in
        let b_rows = 8usize;
        let reps = 8usize;
        let mut mm_rng = Rng::new(43);
        let x: Vec<f32> = (0..b_rows * hidden).map(|_| mm_rng.range(-1.0, 1.0) as f32).collect();
        let w: Vec<f32> = (0..hidden * hidden).map(|_| mm_rng.range(-1.0, 1.0) as f32).collect();
        let mut mm_s = vec![0.0f32; b_rows * hidden];
        let mut mm_d = vec![0.0f32; b_rows * hidden];
        let (sc, di) = ab_throughput(
            budget,
            (reps * b_rows * hidden * hidden) as f64,
            || {
                for _ in 0..reps {
                    tensor::scalar::matmul_into(&x, &w, b_rows, hidden, hidden, &mut mm_s);
                }
            },
            || {
                for _ in 0..reps {
                    tensor::matmul_into(&x, &w, b_rows, hidden, hidden, &mut mm_d);
                }
            },
        );
        println!(
            "mlp {label} matmul: scalar {sc:.3e}/s dispatch {di:.3e}/s ({:.2}x, simd {simd_on})",
            di / sc
        );
        std::hint::black_box((&mm_s, &mm_d));
        mlp_rows.push((
            label.to_string(),
            Json::obj(vec![
                ("steps_per_sec_fused", Json::Num(sps_fused)),
                ("steps_per_sec_unfused", Json::Num(sps_unfused)),
                ("speedup_fused_vs_unfused", Json::Num(speedup)),
                (
                    "matmul",
                    Json::obj(vec![
                        ("scalar_per_sec", Json::Num(sc)),
                        ("dispatch_per_sec", Json::Num(di)),
                        ("speedup_dispatch_vs_scalar", Json::Num(di / sc)),
                    ]),
                ),
            ]),
        ));
    }

    // ---- intra-batch sharding: row-steps/sec at shards ∈ {1, 2, 4} ------
    // Bitwise the same result at every shard count (the equivalence
    // suite pins it); this measures the wall-clock knob.
    let mut shard_rows: Vec<(String, Json)> = Vec::new();
    for &(label, n_z) in &[("n_z=4", 4usize), ("n_z=64", 64usize)] {
        let b = 32usize;
        let toy = LinearToy::new(-0.3, n_z);
        let solver = solver_by_name("alf").unwrap();
        let states: Vec<State> = (0..b)
            .map(|r| {
                let scale = 1.0 + 0.005 * r as f32;
                let z0: Vec<f32> = (0..n_z).map(|i| scale * (1.0 + 0.01 * i as f32)).collect();
                solver.init(&toy, 0.0, &z0)
            })
            .collect();
        let refs: Vec<&State> = states.iter().collect();
        let state0 = BatchState::from_states(&refs);
        let mode = StepMode::Fixed { h: 0.01 };
        let mut base_sps = 0.0f64;
        let mut cells: Vec<(String, Json)> = Vec::new();
        for &s in &[1usize, 2, 4] {
            let mut shards = BatchShards::new(s);
            let pool = if s > 1 { Some(WorkerPool::new(s - 1)) } else { None };
            let mut ws = BatchWorkspace::new();
            let mut per = Vec::new();
            let mut run = || {
                integrate_batch_obs_stats_sharded(
                    &*solver,
                    &toy,
                    0.0,
                    1.0,
                    &state0,
                    &mode,
                    &ErrorNorm::Full,
                    &ObsGrid::none(),
                    |_, _| (),
                    &mut per,
                    &mut shards,
                    &mut ws,
                    pool.as_ref(),
                )
                .unwrap();
                per.iter().map(|p| p.n_accepted as u64).sum::<u64>()
            };
            let row_steps = run().max(1);
            let t = time_until(budget, || {
                std::hint::black_box(run());
            });
            let sps = row_steps as f64 / t.min_s;
            if s == 1 {
                base_sps = sps;
            }
            let speedup = sps / base_sps;
            println!("shards {label} x{s}: {sps:.3e} row-steps/s ({speedup:.2}x vs 1 shard)");
            cells.push((
                format!("shards={s}"),
                Json::obj(vec![
                    ("row_steps_per_sec", Json::Num(sps)),
                    ("speedup_vs_1shard", Json::Num(speedup)),
                ]),
            ));
        }
        shard_rows.push((label.to_string(), Json::Obj(cells.into_iter().collect())));
    }

    // ---- end-to-end gradient configurations -----------------------------
    let n_z = 4usize;
    let batch = 32usize;
    let t_end = 2.0;
    for &(mode_label, fixed) in &[("fixed", true), ("adaptive", false)] {
        for method_name in ["mali", "aca", "naive", "adjoint", "symplectic"] {
            let method = grad_by_name(method_name).unwrap();
            let solver = if method_name == "adjoint" {
                solver_by_name("heun-euler").unwrap()
            } else {
                solver_by_name("alf").unwrap()
            };
            let spec = if fixed {
                IvpSpec::fixed(0.0, t_end, 0.02)
            } else {
                IvpSpec::adaptive(0.0, t_end, 1e-4, 1e-6)
            };

            // solo
            let toy = LinearToy::new(-0.3, n_z);
            let z0: Vec<f32> = (0..n_z).map(|i| 1.0 + 0.01 * i as f32).collect();
            measure_config(
                format!("solo.{mode_label}.{method_name}"),
                budget,
                &mut table,
                &mut configs,
                || {
                    method
                        .grad(&toy, &*solver, &spec, &z0, &SquareLoss, MemTracker::new())
                        .unwrap()
                        .stats
                        .fwd
                        .n_accepted
                },
            );

            // batch (row-steps/sec; one grad_batch call)
            let bspec = BatchSpec::new(batch, n_z);
            let mut z0b = Vec::with_capacity(bspec.flat_len());
            for b in 0..batch {
                let scale = 1.0 + 0.005 * b as f32;
                z0b.extend((0..n_z).map(|i| scale * (1.0 + 0.01 * i as f32)));
            }
            measure_config(
                format!("batch{batch}.{mode_label}.{method_name}"),
                budget,
                &mut table,
                &mut configs,
                || {
                    method
                        .grad_batch(
                            &toy,
                            &*solver,
                            &spec,
                            &z0b,
                            &bspec,
                            &SquareLoss,
                            MemTracker::new(),
                        )
                        .unwrap()
                        .stats
                        .fwd
                        .n_accepted
                },
            );
        }
    }

    table.print();

    // ---- JSON emission ---------------------------------------------------
    if let Json::Obj(map) = &mut root {
        map.insert("bench".into(), Json::Str("perf_hotpath".into()));
        map.insert(
            "provenance".into(),
            Json::Str(if smoke { "measured-smoke" } else { "measured" }.into()),
        );
        map.insert(
            "simd_feature".into(),
            Json::Bool(tensor::simd_enabled()),
        );
        map.insert(
            "kernel".into(),
            Json::Obj(speedups.into_iter().collect()),
        );
        map.insert(
            "tensor".into(),
            Json::Obj(tensor_rows.into_iter().collect()),
        );
        map.insert(
            "mlp".into(),
            Json::Obj(mlp_rows.into_iter().collect()),
        );
        map.insert(
            "shards".into(),
            Json::Obj(shard_rows.into_iter().collect()),
        );
        map.insert(
            "configs".into(),
            Json::Obj(configs.into_iter().collect()),
        );
    }
    let out_path = std::env::var("MALI_BENCH_OUT")
        .unwrap_or_else(|_| "../BENCH_hotpath.json".to_string());
    match std::fs::write(&out_path, root.pretty() + "\n") {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
