//! Hot-path micro-benchmarks for the performance pass (EXPERIMENTS.md
//! §Perf): per-layer timings of everything on the MALI request path.
//!
//! * L1/L2 — one fused ALF ψ / ψ⁻¹ / ψ-vjp PJRT execute per model family
//!   (the Pallas kernel inside the AOT graph), vs the host-composed path
//!   (`f` + host algebra) it replaces.
//! * L3 — full MALI gradient step for the img16 classifier (the Fig. 5
//!   training hot loop) and the adaptive integration loop overhead on
//!   native dynamics (pure coordinator cost, no PJRT).
//!
//! Run: `cargo bench --bench perf_hotpath`

use mali_ode::grad::{by_name as grad_by_name, IvpSpec, SquareLoss};
use mali_ode::models::image::OdeImageClassifier;
use mali_ode::models::SolveCfg;
use mali_ode::runtime::{Engine, HloDynamics};
use mali_ode::solvers::alf::AlfSolver;
use mali_ode::solvers::dynamics::{Dynamics, MlpDynamics};
use mali_ode::util::bench::{time_until, Table};
use mali_ode::util::mem::MemTracker;
use mali_ode::util::rng::Rng;
use std::rc::Rc;

fn main() {
    let engine = Rc::new(Engine::from_env().expect("run `make artifacts`"));
    let mut rng = Rng::new(7);
    let mut table = Table::new(
        "perf_hotpath: per-op / per-step wall time",
        &["op", "mean", "min", "iters"],
    );

    // ---- L1/L2: fused ALF step vs host-composed, per family -------------
    for family in ["img16", "img32", "latent"] {
        let mut dynamics = HloDynamics::new(engine.clone(), family).unwrap();
        dynamics.init_params(&mut rng).unwrap();
        let n = dynamics.dim();
        let mut z = vec![0.0f32; n];
        rng.fill_uniform_sym(&mut z, 0.5);
        let v = dynamics.f(0.0, &z);
        let solver = AlfSolver::new(1.0);

        let t = time_until(0.5, || {
            let _ = solver.psi(&dynamics, 0.0, 0.25, &z, &v);
        });
        table.row(&[
            format!("{family}.step (fused ψ)"),
            t.fmt_ms(),
            format!("{:.3}ms", t.min_s * 1e3),
            t.iters.to_string(),
        ]);

        dynamics.use_fused = false;
        let t = time_until(0.5, || {
            let _ = solver.psi(&dynamics, 0.0, 0.25, &z, &v);
        });
        table.row(&[
            format!("{family}.step (composed f)"),
            t.fmt_ms(),
            format!("{:.3}ms", t.min_s * 1e3),
            t.iters.to_string(),
        ]);
        dynamics.use_fused = true;

        let az = vec![1.0f32; n];
        let av = vec![0.0f32; n];
        let t = time_until(0.5, || {
            let _ = solver.psi_vjp(&dynamics, 0.0, 0.25, &z, &v, &az, &av);
        });
        table.row(&[
            format!("{family}.step_vjp (fused)"),
            t.fmt_ms(),
            format!("{:.3}ms", t.min_s * 1e3),
            t.iters.to_string(),
        ]);
    }

    // ---- L3: full MALI training step (img16) -----------------------------
    {
        let mut model = OdeImageClassifier::new(engine.clone(), "img16", &mut rng).unwrap();
        let mut x = vec![0.0f32; model.batch * model.d_in];
        rng.fill_uniform_sym(&mut x, 0.5);
        let mut y1h = vec![0.0f32; model.batch * model.classes];
        for b in 0..model.batch {
            y1h[b * model.classes + b % model.classes] = 1.0;
        }
        let solver = mali_ode::solvers::by_name("alf").unwrap();
        let method = grad_by_name("mali").unwrap();
        let t = time_until(2.0, || {
            let cfg = SolveCfg {
                solver: &*solver,
                spec: IvpSpec::fixed(0.0, 1.0, 0.25),
                method: &*method,
            };
            let _ = model.step(&x, &y1h, &cfg, false).unwrap();
        });
        table.row(&[
            "img16 full MALI train step".into(),
            t.fmt_ms(),
            format!("{:.3}ms", t.min_s * 1e3),
            t.iters.to_string(),
        ]);
    }

    // ---- L3: pure coordinator overhead (native dynamics, no PJRT) --------
    {
        let dynamics = MlpDynamics::new(32, 64, &mut rng);
        let mut z = vec![0.0f32; 32];
        rng.fill_uniform_sym(&mut z, 0.5);
        let solver = mali_ode::solvers::by_name("alf").unwrap();
        for (label, method_name) in [("mali", "mali"), ("aca", "aca"), ("adjoint", "adjoint")] {
            let method = grad_by_name(method_name).unwrap();
            let t = time_until(0.5, || {
                let tracker = MemTracker::new();
                let spec = IvpSpec::adaptive(0.0, 2.0, 1e-4, 1e-6);
                let _ = method
                    .grad(&dynamics, &*solver, &spec, &z, &SquareLoss, tracker)
                    .unwrap();
            });
            table.row(&[
                format!("native MLP-32 grad ({label})"),
                t.fmt_ms(),
                format!("{:.3}ms", t.min_s * 1e3),
                t.iters.to_string(),
            ]);
        }
    }

    table.print();
}
