//! Bench target for E13 — the TCP front-end under load (DESIGN.md §11):
//! client-observed p50/p99 through the length-prefixed binary transport
//! (window 1, window 8 pipelined, window 8 with connection churn) vs
//! the in-process baseline.  Run with `cargo bench --bench
//! perf_serve_tcp` (add `-- --full` for the EXPERIMENTS.md scale);
//! `runs/serve_tcp.json` is the artifact CI uploads next to
//! `runs/serve.json`.
use mali_ode::coordinator::{exp_serve_tcp, report, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Quick };
    let t0 = std::time::Instant::now();
    let summary = exp_serve_tcp::serve_tcp_bench(scale, 0).expect("perf_serve_tcp");
    report::write_summary("runs", "serve_tcp", &summary).expect("write summary");
    println!(
        "\nperf_serve_tcp done in {:.1}s (runs/serve_tcp.json written)",
        t0.elapsed().as_secs_f64()
    );
}
