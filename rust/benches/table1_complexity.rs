//! Bench target regenerating paper Table 1 (see DESIGN.md §5).
//! Run with `cargo bench --bench table1_complexity` (add `-- --full` for the
//! EXPERIMENTS.md scale).
use mali_ode::coordinator::{exp_toy, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Quick };
    let t0 = std::time::Instant::now();
    let summary = exp_toy::table1(scale, 0).expect("table1_complexity");
    mali_ode::coordinator::report::write_summary("runs", "table1", &summary).expect("write summary");
    println!("\ntable1_complexity done in {:.1}s (runs/table1.json written)", t0.elapsed().as_secs_f64());
}
