//! perf_batch: batched-throughput baseline for the batch-first stack.
//!
//! Measures MALI steps/sec on the E1 toy problem (`dz/dt = αz`,
//! `L = Σ z(T)²`) at B ∈ {1, 8, 64}, comparing
//!
//! * **loop**: B independent single-sample `grad` calls (the only
//!   batching the pre-batch-first stack offered), vs
//! * **batched**: one `grad_batched_pooled` call — vectorized `[B, N_z]`
//!   row arithmetic, native dynamics sharded across `util::pool` workers
//!   (`MALI_THREADS`).
//!
//! The acceptance bar for the refactor: batched MALI at B = 64 ≥ 4× the
//! B = 1-style loop in steps/sec with `MALI_THREADS ≥ 4`.  A steps/sec
//! figure here is forward *accepted row-steps* per wall second (each
//! accepted step also pays its ψ⁻¹ + vjp on the backward pass, so the
//! metric is proportional to end-to-end gradient throughput).
//!
//! Run: `cargo bench --bench perf_batch` (append `-- --full` for longer
//! timing windows).

use mali_ode::grad::batch_driver::grad_batched_pooled;
use mali_ode::grad::mali::Mali;
use mali_ode::grad::{GradMethod, IvpSpec, SquareLoss};
use mali_ode::solvers::alf::AlfSolver;
use mali_ode::solvers::batch::BatchSpec;
use mali_ode::solvers::dynamics::LinearToy;
use mali_ode::util::bench::{time_until, Table};
use mali_ode::util::mem::MemTracker;
use mali_ode::util::pool;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let budget = if full { 2.0 } else { 0.4 };

    // E1 toy setup: contracting scalar dynamics, N_z = 4 per sample.
    let alpha = -0.3;
    let n_z = 4usize;
    let (t_end, h) = (5.0, 0.02);
    let n_steps = (t_end / h_to_grid(h, t_end)).round() as usize; // per sample
    let toy = LinearToy::new(alpha, n_z);
    let solver = AlfSolver::new(1.0);
    let method = Mali;
    let spec = IvpSpec::fixed(0.0, t_end, h);

    println!(
        "perf_batch: MALI on E1 toy (n_z = {n_z}, {n_steps} steps/sample), {} worker threads",
        pool::num_threads()
    );
    let mut table = Table::new(
        "batched MALI throughput vs per-sample loop (fixed step)",
        &["B", "loop steps/s", "batched steps/s", "speedup"],
    );

    let mut speedup_at_64 = 0.0f64;
    for &bsz in &[1usize, 8, 64] {
        let bspec = BatchSpec::new(bsz, n_z);
        let mut z0 = Vec::with_capacity(bspec.flat_len());
        for b in 0..bsz {
            let scale = 1.0 + 0.01 * b as f32;
            z0.extend([1.0 * scale, 0.5 * scale, -0.8 * scale, 1.5 * scale]);
        }

        // (a) the pre-refactor shape: one solo grad per sample
        let t_loop = time_until(budget, || {
            for b in 0..bsz {
                let _ = method
                    .grad(
                        &toy,
                        &solver,
                        &spec,
                        bspec.row(&z0, b),
                        &SquareLoss,
                        MemTracker::new(),
                    )
                    .unwrap();
            }
        });

        // (b) one pooled batched call
        let t_batch = time_until(budget, || {
            let _ = grad_batched_pooled(
                &method,
                &toy,
                &solver,
                &spec,
                &z0,
                &bspec,
                &SquareLoss,
                MemTracker::new(),
            )
            .unwrap();
        });

        let row_steps = (bsz * n_steps) as f64;
        let loop_sps = row_steps / t_loop.mean_s;
        let batch_sps = row_steps / t_batch.mean_s;
        let speedup = batch_sps / loop_sps;
        if bsz == 64 {
            speedup_at_64 = speedup;
        }
        table.row(&[
            bsz.to_string(),
            format!("{loop_sps:.0}"),
            format!("{batch_sps:.0}"),
            format!("{speedup:.2}x"),
        ]);
    }
    table.print();
    println!(
        "\nB=64 batched speedup over per-sample loop: {speedup_at_64:.2}x (target >= 4x with MALI_THREADS >= 4)"
    );

    // informative: adaptive mode, where the active mask lets early-converged
    // rows stop consuming f evals
    let aspec = IvpSpec::adaptive(0.0, t_end, 1e-5, 1e-7);
    let bspec = BatchSpec::new(64, n_z);
    let mut z0 = Vec::with_capacity(bspec.flat_len());
    for b in 0..64 {
        let scale = 0.05 + 0.03 * b as f32; // widely spread → desynced grids
        z0.extend([1.0 * scale, 0.5 * scale, -0.8 * scale, 1.5 * scale]);
    }
    let res = grad_batched_pooled(
        &method,
        &toy,
        &solver,
        &aspec,
        &z0,
        &bspec,
        &SquareLoss,
        MemTracker::new(),
    )
    .unwrap();
    let t_adapt = time_until(budget, || {
        let _ = grad_batched_pooled(
            &method,
            &toy,
            &solver,
            &aspec,
            &z0,
            &bspec,
            &SquareLoss,
            MemTracker::new(),
        )
        .unwrap();
    });
    let accepted: usize = res.per_sample_fwd.iter().map(|s| s.n_accepted).sum();
    println!(
        "adaptive B=64: {} accepted row-steps ({}..{} per sample), {:.0} steps/s",
        accepted,
        res.per_sample_fwd.iter().map(|s| s.n_accepted).min().unwrap_or(0),
        res.per_sample_fwd.iter().map(|s| s.n_accepted).max().unwrap_or(0),
        accepted as f64 / t_adapt.mean_s
    );
}

/// The fixed-mode grid actually taken: n equal steps of |h'| ≤ h landing
/// exactly on t_end (mirrors `integrate`'s grid construction).
fn h_to_grid(h: f64, span: f64) -> f64 {
    let n = (span.abs() / h).ceil().max(1.0);
    span / n
}
