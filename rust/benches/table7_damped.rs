//! Bench target regenerating paper Table 7 (see DESIGN.md §5).
//! Run with `cargo bench --bench table7_damped` (add `-- --full` for the
//! EXPERIMENTS.md scale).
use mali_ode::coordinator::{exp_series, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Quick };
    let t0 = std::time::Instant::now();
    let summary = exp_series::table7(scale, 0).expect("table7_damped");
    mali_ode::coordinator::report::write_summary("runs", "table7", &summary).expect("write summary");
    println!("\ntable7_damped done in {:.1}s (runs/table7.json written)", t0.elapsed().as_secs_f64());
}
