//! Bench target regenerating paper Table 4 (see DESIGN.md §5).
//! Run with `cargo bench --bench table4_mujoco` (add `-- --full` for the
//! EXPERIMENTS.md scale).
use mali_ode::coordinator::{exp_series, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Quick };
    let t0 = std::time::Instant::now();
    let summary = exp_series::table4(scale, 0).expect("table4_mujoco");
    mali_ode::coordinator::report::write_summary("runs", "table4", &summary).expect("write summary");
    println!("\ntable4_mujoco done in {:.1}s (runs/table4.json written)", t0.elapsed().as_secs_f64());
}
