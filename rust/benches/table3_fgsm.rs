//! Bench target regenerating paper Table 3 (see DESIGN.md §5).
//! Run with `cargo bench --bench table3_fgsm` (add `-- --full` for the
//! EXPERIMENTS.md scale).
use mali_ode::coordinator::{exp_images, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Quick };
    let t0 = std::time::Instant::now();
    let summary = exp_images::table3(scale, 0).expect("table3_fgsm");
    mali_ode::coordinator::report::write_summary("runs", "table3", &summary).expect("write summary");
    println!("\ntable3_fgsm done in {:.1}s (runs/table3.json written)", t0.elapsed().as_secs_f64());
}
